"""Jaxpr abstract interpretation over the :mod:`repro.analysis.interval` domain.

:func:`analyze_jaxpr` walks a closed jaxpr, propagating an
:class:`~repro.analysis.interval.Interval` per variable, and records
**events** at hazardous primitives (division, log, rsqrt, ...) for the
checkers to turn into findings.  Three mechanics beyond plain interval
arithmetic:

* **Sub-jaxpr recursion with provenance.**  ``jnp.where`` traces to a
  ``pjit[name=_where]`` wrapping ``select_n``; every higher-order primitive
  (``pjit``, ``custom_jvp_call``, ``while``, ``scan``, ``cond``,
  ``remat``/``checkpoint``) is entered with an environment mapping so
  refinement information crosses the call boundary.  Each abstract value
  carries a *provenance token* — the id of the outermost variable it is a
  pass-through of — so a comparison on ``x`` can refine a ``select_n`` case
  that is ``x`` routed through a pjit boundary.

* **Predicate refinement at select_n.**  ``select_n(pred, on_false,
  on_true)`` with ``pred = gt(x, c)`` (or ge/lt/le/isfinite) narrows the
  interval of the ``on_true`` case when that case *is* ``x`` (by
  provenance), and symmetrically for ``on_false``.  This is exactly how a
  double-``where`` guard proves the guarded denominator non-zero — and why
  reverting the guard (dividing *before* the select) re-fires the hazard.

* **while fixpoint with widening.**  Loop bodies are iterated with the
  carry intervals joined; after a few iterations unstable bounds widen to
  open infinities ("unbounded but finite"), which terminates and stays
  sound for the attainability predicates.

Unknown primitives produce :data:`~repro.analysis.interval.FINITE_TOP` and
are recorded as coverage gaps rather than silently trusted.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable

from .interval import BOOL, FINITE_TOP, TOP, Interval

__all__ = ["AbsValue", "Event", "Analysis", "analyze_jaxpr", "format_frame"]

_INF = math.inf


@dataclass(frozen=True)
class AbsValue:
    """An interval plus the provenance token of the value it passes through.

    ``origin`` is an opaque token (the jaxpr ``Var`` object at the outermost
    scope where the value was introduced).  It survives shape-only ops
    (broadcast, convert, reshape, ...) and sub-jaxpr boundaries, so guard
    predicates can be matched to the value they actually constrain.
    """

    ival: Interval
    origin: Any = None

    def with_ival(self, ival: Interval) -> "AbsValue":
        # a changed interval from a pass-through op keeps the origin;
        # callers that compute fresh values should construct AbsValue anew
        return AbsValue(ival, self.origin)


@dataclass
class Event:
    """One potentially hazardous primitive occurrence."""

    kind: str                 # "div0", "inf_minus_inf", "log_domain", ...
    prim: str                 # primitive name
    frame: Any                # source_info_util Frame or None
    detail: str               # human-readable interval story
    chain: tuple[str, ...]    # enclosing higher-order primitive path


@dataclass
class Analysis:
    """Result of one :func:`analyze_jaxpr` run."""

    events: list[Event] = field(default_factory=list)
    unknown_prims: set[str] = field(default_factory=set)
    out_vals: list[AbsValue] = field(default_factory=list)


# ---------------------------------------------------------------------------
# source locations
# ---------------------------------------------------------------------------


def _user_frame(eqn):
    try:
        from jax._src import source_info_util
        return source_info_util.user_frame(eqn.source_info)
    except Exception:
        return None


def format_frame(frame) -> str:
    if frame is None:
        return "<unknown>"
    fn = getattr(frame, "file_name", "?")
    line = getattr(frame, "start_line", getattr(frame, "line_num", 0))
    func = getattr(frame, "function_name", "?")
    return f"{fn}:{line} in {func}"


# ---------------------------------------------------------------------------
# the interpreter
# ---------------------------------------------------------------------------

# ops whose single data operand passes through unchanged enough to keep
# provenance (shape/dtype adjustments and no-op math)
_PASS_THROUGH = {
    "broadcast_in_dim", "convert_element_type", "reshape", "squeeze",
    "expand_dims", "copy", "stop_gradient", "slice", "dynamic_slice",
    "transpose", "rev", "gather", "reduce_precision",
}

# comparison primitive -> (refinement for TRUE case, refinement for FALSE case)
# as functions of the comparison constant interval
def _refine_gt(c: Interval):
    t = Interval(c.lo, _INF, True, True)        # x > c: lo open at c.lo
    f = Interval(-_INF, c.hi, True, False)      # x <= c
    return t, f


def _refine_ge(c: Interval):
    t = Interval(c.lo, _INF, False, True)
    f = Interval(-_INF, c.hi, True, True)
    return t, f


def _refine_lt(c: Interval):
    t = Interval(-_INF, c.hi, True, True)
    f = Interval(c.lo, _INF, False, True)
    return t, f


def _refine_le(c: Interval):
    t = Interval(-_INF, c.hi, True, False)
    f = Interval(c.lo, _INF, True, True)
    return t, f


def _refine_isfinite(_c: Interval):
    t = Interval(-_INF, _INF, True, True)       # finite: open infinities
    f = TOP
    return t, f


_CMP_REFINERS: dict[str, Callable] = {
    "gt": _refine_gt, "ge": _refine_ge, "lt": _refine_lt, "le": _refine_le,
    "is_finite": _refine_isfinite,
}


@dataclass
class _Guard:
    """pred_var -> (origin being constrained, true-interval, false-interval)."""

    origin: Any
    true_ival: Interval
    false_ival: Interval


class _Interp:
    def __init__(self, analysis: Analysis, *,
                 grad_mode: bool = False,
                 max_while_iters: int = 3):
        self.an = analysis
        self.grad_mode = grad_mode
        self.max_while_iters = max_while_iters
        # predicate provenance: var-id of a boolean -> _Guard
        self.guards: dict[int, _Guard] = {}
        # values derived purely from comparisons/constants (validity flags);
        # stop_gradient on these is benign for the grad-blocker
        self.bool_derived: set[int] = set()
        self.chain: list[str] = []

    # ---- environment helpers ----

    @staticmethod
    def _is_literal(v) -> bool:
        return hasattr(v, "val") and not hasattr(v, "count")

    def read(self, env: dict, v) -> AbsValue:
        if self._is_literal(v):
            import numpy as np
            val = np.asarray(v.val)
            if val.size == 1:
                return AbsValue(Interval.point(float(val.reshape(-1)[0])), v)
            lo = float(val.min())
            hi = float(val.max())
            if math.isnan(lo) or math.isnan(hi):
                return AbsValue(Interval.point(float("nan")), v)
            return AbsValue(Interval(min(lo, hi), max(lo, hi)), v)
        return env[v]

    def is_bool_derived(self, env: dict, v) -> bool:
        if self._is_literal(v):
            return True
        av = env.get(v)
        if av is None:
            return False
        if id(av.origin) in self.bool_derived:
            return True
        # point-constants (e.g. a literal routed through a sub-jaxpr invar)
        # carry no gradient — neutral for the validity-flag taint
        iv = av.ival
        return iv.lo == iv.hi and not iv.maybe_nan

    def record(self, kind: str, eqn, detail: str):
        self.an.events.append(Event(
            kind=kind,
            prim=eqn.primitive.name,
            frame=_user_frame(eqn),
            detail=detail,
            chain=tuple(self.chain),
        ))

    # ---- main walk ----

    def run(self, jaxpr, in_vals: list[AbsValue]) -> list[AbsValue]:
        env: dict = {}
        for v, av in zip(jaxpr.invars, in_vals):
            # give fresh provenance to inputs that have none
            env[v] = av if av.origin is not None else AbsValue(av.ival, v)
        for cv in jaxpr.constvars:
            env[cv] = AbsValue(self._const_ival(cv), cv)
        for eqn in jaxpr.eqns:
            self.eqn(env, eqn)
        return [self.read(env, v) for v in jaxpr.outvars]

    def _const_ival(self, cv) -> Interval:
        aval = getattr(cv, "aval", None)
        # consts in closed jaxprs are bound separately; a bare constvar in a
        # sub-jaxpr is opaque here — treat as finite-unknown
        del aval
        return FINITE_TOP

    def run_closed(self, closed_jaxpr, in_vals: list[AbsValue]) -> list[AbsValue]:
        import numpy as np

        jaxpr = closed_jaxpr.jaxpr
        env: dict = {}
        for cv, cval in zip(jaxpr.constvars, closed_jaxpr.consts):
            try:
                arr = np.asarray(cval)
                if arr.dtype.kind in "fiub" and arr.size:
                    lo, hi = float(arr.min()), float(arr.max())
                    if math.isnan(lo) or math.isnan(hi):
                        ival = Interval.point(float("nan"))
                    else:
                        ival = Interval(lo, hi)
                else:
                    ival = FINITE_TOP
            except Exception:
                ival = FINITE_TOP
            env[cv] = AbsValue(ival, cv)
        for v, av in zip(jaxpr.invars, in_vals):
            env[v] = av if av.origin is not None else AbsValue(av.ival, v)
        for eqn in jaxpr.eqns:
            self.eqn(env, eqn)
        return [self.read(env, v) for v in jaxpr.outvars]

    # ---- per-equation transfer ----

    def eqn(self, env: dict, eqn):
        name = eqn.primitive.name
        handler = getattr(self, f"prim_{name}", None)
        if handler is not None:
            outs = handler(env, eqn)
        elif name in _PASS_THROUGH:
            src = self.read(env, eqn.invars[0])
            if name == "stop_gradient" and self.grad_mode \
                    and not self.is_bool_derived(env, eqn.invars[0]):
                self.record("stop_gradient", eqn,
                            "stop_gradient on a non-boolean value inside a "
                            "differentiated path zeroes its cotangent")
            outs = [src] * len(eqn.outvars)
        else:
            outs = self.generic(env, eqn)
        for v, av in zip(eqn.outvars, outs):
            env[v] = av

    def generic(self, env: dict, eqn):
        name = eqn.primitive.name
        ins = [self.read(env, v) for v in eqn.invars]
        out_ival = self._generic_ival(name, ins, eqn)
        if out_ival is None:
            self.an.unknown_prims.add(name)
            out_ival = FINITE_TOP
        return [AbsValue(out_ival, eqn.outvars[0] if eqn.outvars else None)
                for _ in eqn.outvars]

    # transfer functions for first-order math prims without special handling
    def _generic_ival(self, name: str, ins: list[AbsValue], eqn):
        iv = [a.ival for a in ins]
        if name in ("reduce_sum", "cumsum", "cumlogsumexp", "add_any"):
            # bounded-count over-approximation: n * per-element hull
            n = self._reduction_count(eqn)
            return iv[0].scale_by_count(n)
        if name in ("reduce_max", "reduce_min", "cummax", "cummin",
                    "reduce_and", "reduce_or", "argmax", "argmin",
                    "reduce_prod", "sort"):
            if name == "reduce_prod":
                return FINITE_TOP if not iv[0].maybe_nan else \
                    Interval(-_INF, _INF, True, True, True)
            if name in ("argmax", "argmin"):
                return Interval(0.0, _INF, False, True)
            if name in ("reduce_and", "reduce_or"):
                return BOOL
            return iv[0]
        if name in ("sin", "cos"):
            return Interval(-1.0, 1.0, maybe_nan=iv[0].maybe_nan
                            or iv[0].attains_inf)
        if name == "tanh":
            return Interval(-1.0, 1.0, maybe_nan=iv[0].maybe_nan)
        if name == "logistic":
            return Interval(0.0, 1.0, True, True, iv[0].maybe_nan)
        if name == "sign":
            return Interval(-1.0, 1.0, maybe_nan=iv[0].maybe_nan)
        if name in ("iota",):
            return Interval(0.0, _INF, False, True)
        if name in ("and", "or", "xor", "not"):
            return BOOL
        if name in ("eq", "ne"):
            return BOOL
        if name in ("clamp",):
            lo, x, hi = iv
            return x.max_(lo).min_(hi)
        if name in ("nextafter",):
            return iv[0]
        if name in ("erf",):
            return Interval(-1.0, 1.0, maybe_nan=iv[0].maybe_nan)
        if name in ("concatenate", "pad", "select_and_scatter_add",
                    "scatter", "scatter_add", "dynamic_update_slice"):
            out = iv[0]
            for other in iv[1:]:
                out = out.hull(other)
            return out
        if name in ("dot_general", "conv_general_dilated"):
            a, b = iv[0], iv[1]
            prod = a.mul(b)
            return prod.scale_by_count(self._reduction_count(eqn, default=64))
        if name == "square":
            return iv[0].mul(iv[0])
        if name == "percentile":
            return iv[0]
        return None

    @staticmethod
    def _reduction_count(eqn, default: int = 1 << 20) -> int:
        try:
            shape = eqn.invars[0].aval.shape
            n = 1
            for d in shape:
                n *= int(d)
            return max(n, 1)
        except Exception:
            return default

    # ---- arithmetic prims ----

    def _taint_binop(self, env, eqn):
        """Propagate the validity-flag taint: a value computed only from
        comparisons/constants stays bool-derived through arithmetic."""
        if all(self.is_bool_derived(env, v) for v in eqn.invars):
            self.bool_derived.add(id(eqn.outvars[0]))

    def _binop(self, env, eqn, fn) -> list[AbsValue]:
        a = self.read(env, eqn.invars[0])
        b = self.read(env, eqn.invars[1])
        self._taint_binop(env, eqn)
        return [AbsValue(fn(a.ival, b.ival), eqn.outvars[0])]

    def prim_add(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        b = self.read(env, eqn.invars[1])
        out = a.ival.add(b.ival)
        if (a.ival.attains_pinf and b.ival.attains_ninf) or \
                (a.ival.attains_ninf and b.ival.attains_pinf):
            self.record("inf_minus_inf", eqn,
                        f"add of {a.ival} and {b.ival} can be inf + -inf")
        self._taint_binop(env, eqn)
        return [AbsValue(out, eqn.outvars[0])]

    def prim_sub(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        b = self.read(env, eqn.invars[1])
        out = a.ival.sub(b.ival)
        if (a.ival.attains_pinf and b.ival.attains_pinf) or \
                (a.ival.attains_ninf and b.ival.attains_ninf):
            self.record("inf_minus_inf", eqn,
                        f"sub of {a.ival} and {b.ival} can be inf - inf")
        return [AbsValue(out, eqn.outvars[0])]

    def prim_mul(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        b = self.read(env, eqn.invars[1])
        if (a.ival.attains_inf and b.ival.attains_zero) or \
                (a.ival.attains_zero and b.ival.attains_inf):
            self.record("zero_times_inf", eqn,
                        f"mul of {a.ival} and {b.ival} can be 0 * inf")
        self._taint_binop(env, eqn)
        return [AbsValue(a.ival.mul(b.ival), eqn.outvars[0])]

    def prim_div(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        b = self.read(env, eqn.invars[1])
        if b.ival.attains_zero:
            self.record("div0", eqn,
                        f"denominator {b.ival} attains 0 "
                        f"(numerator {a.ival})")
        if a.ival.attains_inf and b.ival.attains_inf:
            self.record("inf_over_inf", eqn,
                        f"inf/inf possible: {a.ival} / {b.ival}")
        return [AbsValue(a.ival.div(b.ival), eqn.outvars[0])]

    def prim_rem(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        b = self.read(env, eqn.invars[1])
        if b.ival.attains_zero:
            self.record("div0", eqn, f"mod denominator {b.ival} attains 0")
        hi = b.ival.abs_().hi
        return [AbsValue(Interval(-hi, hi, True, True,
                                  a.ival.maybe_nan or b.ival.maybe_nan),
                         eqn.outvars[0])]

    def prim_max(self, env, eqn):
        return self._binop(env, eqn, Interval.max_)

    def prim_min(self, env, eqn):
        return self._binop(env, eqn, Interval.min_)

    def prim_neg(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        return [AbsValue(a.ival.neg(), eqn.outvars[0])]

    def prim_abs(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        return [AbsValue(a.ival.abs_(), eqn.outvars[0])]

    def prim_pow(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        b = self.read(env, eqn.invars[1])
        if a.ival.contains_negative() and not (
                b.ival.lo == b.ival.hi and float(b.ival.lo).is_integer()):
            self.record("pow_domain", eqn,
                        f"negative base {a.ival} to non-integer power {b.ival}")
        return [AbsValue(FINITE_TOP, eqn.outvars[0])]

    def prim_integer_pow(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        y = int(eqn.params.get("y", 2))
        out = Interval.point(1.0)
        base = a.ival
        if y == 2:
            out = base.mul(base)
        elif y > 0:
            out = base
            for _ in range(min(y - 1, 4)):
                out = out.mul(base)
        elif y < 0:
            inv = Interval.point(1.0).div(base)
            if base.attains_zero:
                self.record("div0", eqn,
                            f"integer_pow({y}) of {base} divides by 0")
            out = inv
        return [AbsValue(out, eqn.outvars[0])]

    # ---- domain-restricted unary prims ----

    def _domain_unary(self, env, eqn, kind, lo_bad, fn, nan_at=None):
        a = self.read(env, eqn.invars[0])
        if a.ival.lo < lo_bad or (nan_at is not None and a.ival.attains(nan_at)):
            self.record(kind, eqn,
                        f"argument {a.ival} reaches the singular domain")
        return [AbsValue(a.ival.monotone(fn, nan_below=lo_bad, nan_at=nan_at),
                         eqn.outvars[0])]

    def prim_log(self, env, eqn):
        return self._domain_unary(env, eqn, "log_domain", 0.0, math.log,
                                  nan_at=0.0)

    def prim_log1p(self, env, eqn):
        return self._domain_unary(env, eqn, "log_domain", -1.0, math.log1p,
                                  nan_at=-1.0)

    def prim_sqrt(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        if a.ival.lo < 0:
            self.record("sqrt_domain", eqn,
                        f"argument {a.ival} can be negative")
        return [AbsValue(a.ival.monotone(math.sqrt, nan_below=0.0),
                         eqn.outvars[0])]

    def prim_rsqrt(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        if a.ival.lo < 0:
            self.record("sqrt_domain", eqn,
                        f"rsqrt argument {a.ival} can be negative")
        if a.ival.attains_zero:
            self.record("div0", eqn, f"rsqrt argument {a.ival} attains 0")
        return [AbsValue(Interval(0.0, _INF,
                                  True, not a.ival.attains_zero,
                                  a.ival.maybe_nan or a.ival.lo < 0),
                         eqn.outvars[0])]

    def prim_exp(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        return [AbsValue(a.ival.monotone(math.exp), eqn.outvars[0])]

    def prim_expm1(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        return [AbsValue(a.ival.monotone(math.expm1), eqn.outvars[0])]

    def prim_exp2(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        return [AbsValue(a.ival.monotone(lambda v: 2.0 ** min(v, 1e3)),
                         eqn.outvars[0])]

    # ---- rounding (grad-relevant) ----

    def _rounding(self, env, eqn, mode):
        if self.grad_mode:
            self.record("rounding", eqn,
                        f"{mode} has zero derivative — gradients through "
                        "this path silently vanish")
        a = self.read(env, eqn.invars[0])
        return [AbsValue(a.ival.round_like(mode), eqn.outvars[0])]

    def prim_floor(self, env, eqn):
        return self._rounding(env, eqn, "floor")

    def prim_ceil(self, env, eqn):
        return self._rounding(env, eqn, "ceil")

    def prim_round(self, env, eqn):
        return self._rounding(env, eqn, "round")

    # ---- comparisons: register guards ----

    def _comparison(self, env, eqn, name):
        a = self.read(env, eqn.invars[0])
        b = self.read(env, eqn.invars[1])
        out = AbsValue(BOOL, eqn.outvars[0])
        refiner = _CMP_REFINERS.get(name)
        if refiner is not None and a.origin is not None:
            const = b.ival
            t, f = refiner(const)
            self.guards[id(eqn.outvars[0])] = _Guard(a.origin, t, f)
        self.bool_derived.add(id(eqn.outvars[0]))
        env[eqn.outvars[0]] = out
        return [out]

    def prim_gt(self, env, eqn):
        return self._comparison(env, eqn, "gt")

    def prim_ge(self, env, eqn):
        return self._comparison(env, eqn, "ge")

    def prim_lt(self, env, eqn):
        return self._comparison(env, eqn, "lt")

    def prim_le(self, env, eqn):
        return self._comparison(env, eqn, "le")

    def prim_is_finite(self, env, eqn):
        a = self.read(env, eqn.invars[0])
        out = AbsValue(BOOL, eqn.outvars[0])
        if a.origin is not None:
            t, f = _refine_isfinite(a.ival)
            self.guards[id(eqn.outvars[0])] = _Guard(a.origin, t, f)
        self.bool_derived.add(id(eqn.outvars[0]))
        return [out]

    def prim_convert_element_type(self, env, eqn):
        src = self.read(env, eqn.invars[0])
        new_dtype = eqn.params.get("new_dtype")
        if self.grad_mode and new_dtype is not None and \
                getattr(new_dtype, "kind", "f") in "iub" and \
                getattr(eqn.invars[0].aval.dtype, "kind", "f") == "f" and \
                not self.is_bool_derived(env, eqn.invars[0]):
            self.record("int_cast", eqn,
                        "float -> integer cast inside a differentiated path")
        if self.is_bool_derived(env, eqn.invars[0]):
            self.bool_derived.add(id(eqn.outvars[0]))
        # bool -> float conversions land in [0, 1]
        src_dtype = getattr(eqn.invars[0].aval, "dtype", None)
        if src_dtype is not None and getattr(src_dtype, "kind", "") == "b":
            return [AbsValue(BOOL, src.origin)]
        return [src]

    # ---- selection with guard refinement ----

    def prim_select_n(self, env, eqn):
        pred_v = eqn.invars[0]
        cases = [self.read(env, v) for v in eqn.invars[1:]]
        guard = None if self._is_literal(pred_v) else \
            self.guards.get(id(self._guard_key(env, pred_v)))
        refined = []
        for idx, case in enumerate(cases):
            ival = case.ival
            if guard is not None and case.origin is guard.origin:
                # select_n(pred, on_false, on_true)
                ref = guard.true_ival if idx == 1 else guard.false_ival
                ival = ival.intersect(ref)
            refined.append(ival)
        out = refined[0]
        for iv in refined[1:]:
            out = out.hull(iv)
        if all(self.is_bool_derived(env, v) for v in eqn.invars[1:]):
            self.bool_derived.add(id(eqn.outvars[0]))
        return [AbsValue(out, eqn.outvars[0])]

    def _guard_key(self, env, pred_v):
        """The variable whose guard entry applies to this predicate: the
        predicate itself, or — if it is a pass-through of another var —
        its origin."""
        if id(pred_v) in self.guards:
            return pred_v
        av = env.get(pred_v)
        if av is not None and av.origin is not None:
            return av.origin
        return pred_v

    # ---- higher-order prims ----

    def _enter(self, tag: str):
        self.chain.append(tag)

    def _exit(self):
        self.chain.pop()

    def _sub_jaxpr_vals(self, env, eqn, invars) -> list[AbsValue]:
        return [self.read(env, v) for v in invars]

    def prim_pjit(self, env, eqn):
        closed = eqn.params["jaxpr"]
        name = eqn.params.get("name", "pjit")
        ins = self._sub_jaxpr_vals(env, eqn, eqn.invars)
        self._enter(f"pjit:{name}")
        try:
            outs = self.run_closed(closed, ins)
        finally:
            self._exit()
        return [AbsValue(o.ival, o.origin) for o in outs]

    def prim_closed_call(self, env, eqn):
        return self.prim_pjit(env, eqn)

    def prim_core_call(self, env, eqn):
        closed = eqn.params.get("call_jaxpr")
        ins = self._sub_jaxpr_vals(env, eqn, eqn.invars)
        self._enter("call")
        try:
            if hasattr(closed, "consts"):
                outs = self.run_closed(closed, ins)
            else:
                outs = self.run(closed, ins)
        finally:
            self._exit()
        return outs

    prim_remat2 = prim_core_call
    prim_checkpoint = prim_core_call

    def prim_custom_jvp_call(self, env, eqn):
        closed = eqn.params["call_jaxpr"]
        ins = self._sub_jaxpr_vals(env, eqn, eqn.invars)
        # grad-blocker principle: inside custom_jvp the author owns the
        # gradient — rounding there is intentional (the ste_* pattern)
        saved, self.grad_mode = self.grad_mode, False
        self._enter("custom_jvp")
        try:
            outs = self.run_closed(closed, ins)
        finally:
            self._exit()
            self.grad_mode = saved
        return outs

    def prim_custom_vjp_call(self, env, eqn):
        closed = eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr")
        ins = self._sub_jaxpr_vals(env, eqn, eqn.invars)
        saved, self.grad_mode = self.grad_mode, False
        self._enter("custom_vjp")
        try:
            outs = self.run_closed(closed, ins)
        finally:
            self._exit()
            self.grad_mode = saved
        return outs

    prim_custom_vjp_call_jaxpr = prim_custom_vjp_call

    def prim_while(self, env, eqn):
        p = eqn.params
        body, cond = p["body_jaxpr"], p["cond_jaxpr"]
        nb, nc = p.get("body_nconsts", 0), p.get("cond_nconsts", 0)
        ins = self._sub_jaxpr_vals(env, eqn, eqn.invars)
        cond_consts = ins[:nc]
        body_consts = ins[nc:nc + nb]
        carry = ins[nc + nb:]
        del cond_consts, cond
        self._enter("while")
        try:
            for it in range(self.max_while_iters + 1):
                outs = self.run_closed(body, body_consts + carry)
                new_carry = []
                changed = False
                for old, new in zip(carry, outs):
                    if it >= self.max_while_iters:
                        joined = old.ival.widen_against(new.ival)
                    else:
                        joined = old.ival.hull(new.ival)
                    if joined != old.ival:
                        changed = True
                    new_carry.append(AbsValue(joined, old.origin))
                carry = new_carry
                if not changed:
                    break
        finally:
            self._exit()
        return carry

    def prim_scan(self, env, eqn):
        p = eqn.params
        body = p["jaxpr"]
        n_consts = p.get("num_consts", 0)
        n_carry = p.get("num_carry", 0)
        length = int(p.get("length", 1) or 1)
        ins = self._sub_jaxpr_vals(env, eqn, eqn.invars)
        consts = ins[:n_consts]
        carry = ins[n_consts:n_consts + n_carry]
        xs = ins[n_consts + n_carry:]
        self._enter("scan")
        ys_hull: list[Interval] | None = None
        try:
            iters = min(length, self.max_while_iters + 1)
            for it in range(iters):
                outs = self.run_closed(body, consts + carry + xs)
                new_carry = outs[:n_carry]
                ys = outs[n_carry:]
                joined = []
                for old, new in zip(carry, new_carry):
                    if it >= self.max_while_iters or iters < length:
                        j = old.ival.widen_against(new.ival) \
                            if it == iters - 1 and iters < length \
                            else old.ival.hull(new.ival)
                    else:
                        j = old.ival.hull(new.ival)
                    joined.append(AbsValue(j, old.origin))
                carry = joined
                cur = [y.ival for y in ys]
                ys_hull = cur if ys_hull is None else [
                    a.hull(b) for a, b in zip(ys_hull, cur)]
        finally:
            self._exit()
        ys_vals = [AbsValue(iv, None) for iv in (ys_hull or [])]
        return carry + ys_vals

    def prim_cond(self, env, eqn):
        branches = eqn.params["branches"]
        ins = self._sub_jaxpr_vals(env, eqn, eqn.invars)
        operands = ins[1:]
        self._enter("cond")
        try:
            branch_outs = [self.run_closed(br, list(operands))
                           for br in branches]
        finally:
            self._exit()
        n_out = len(branch_outs[0])
        outs = []
        for i in range(n_out):
            iv = branch_outs[0][i].ival
            for bo in branch_outs[1:]:
                iv = iv.hull(bo[i].ival)
            outs.append(AbsValue(iv, None))
        return outs


def analyze_jaxpr(closed_jaxpr, in_intervals: list[Interval], *,
                  grad_mode: bool = False) -> Analysis:
    """Walk ``closed_jaxpr`` with the given input intervals.

    ``grad_mode`` additionally records rounding / stop_gradient / int-cast
    events (the grad-blocker hazard set) — use it on jaxprs whose inputs are
    differentiated.
    """
    analysis = Analysis()
    interp = _Interp(analysis, grad_mode=grad_mode)
    in_vals = [AbsValue(iv) for iv in in_intervals]
    analysis.out_vals = interp.run_closed(closed_jaxpr, in_vals)
    return analysis
