"""Abstract interval domain for jaxpr-level NaN-safety analysis.

An :class:`Interval` over-approximates the set of values a jaxpr variable can
take, given the physical axis bounds of :class:`repro.spec.ParamSpace` as the
initial abstraction.  Two refinements beyond textbook interval arithmetic
make it precise enough to verify the repo's masking idioms:

* **Open endpoints.**  ``lo_open`` / ``hi_open`` record whether the endpoint
  value itself is *attainable*.  An unbounded axis like ``pSortMB`` has the
  interval ``(0, +inf)`` with both ends open: it can be arbitrarily large but
  never *equals* ``inf``.  Actual infinities enter a program only through
  literal ``jnp.inf`` (the masking idiom) or a division whose denominator
  attains 0 — exactly the events the nan-hazard checker cares about.  This
  distinction is what keeps the checker from drowning in false ``inf - inf``
  reports: ``x - y`` over two merely-unbounded values is finite, while
  ``where(ok, cost, inf) - where(ok2, cost2, inf)`` really can be NaN.

* **Attainability-aware hazard predicates.**  :meth:`attains_zero`,
  :meth:`attains_pinf` and :meth:`attains_ninf` ask whether the *endpoint
  itself* is reachable — ``(0, 1]`` does not attain zero, ``[0, 1]`` does.
  A double-``where`` guard (PR 6) works precisely because the guarded
  denominator's interval is refined to an open-at-zero interval inside the
  taken branch; revert the guard and the closed zero bound reappears.

Interval arithmetic here is *conservative*: when an exact open/closed
endpoint computation would be intricate (e.g. products of mixed-sign
intervals), the result widens toward closed (= attained) endpoints, which
can only create false positives, never false negatives, in the hazard
checks.  NaN possibility is tracked separately via ``maybe_nan``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace

__all__ = ["Interval", "TOP", "FINITE_TOP", "NONNEG", "UNIT", "BOOL"]

_INF = math.inf


@dataclass(frozen=True)
class Interval:
    """A set of reals ``{x : lo (<|<=) x (<|<=) hi}``, possibly plus NaN."""

    lo: float
    hi: float
    lo_open: bool = False
    hi_open: bool = False
    maybe_nan: bool = False

    # ---------------- constructors ----------------

    @staticmethod
    def point(v: float) -> "Interval":
        if math.isnan(v):
            # a literal NaN: empty numeric range, definitely NaN
            return Interval(_INF, -_INF, True, True, maybe_nan=True)
        return Interval(v, v)

    @staticmethod
    def bounded(lo, hi, lo_open=False, hi_open=False) -> "Interval":
        lo = -_INF if lo is None else float(lo)
        hi = _INF if hi is None else float(hi)
        # an infinite endpoint coming from "no declared bound" is a limit,
        # never an attained value
        if lo == -_INF:
            lo_open = True
        if hi == _INF:
            hi_open = True
        return Interval(lo, hi, lo_open, hi_open)

    # ---------------- hazard predicates ----------------

    def attains(self, v: float) -> bool:
        """Is the exact value ``v`` a member of the set?"""
        if self.lo < v < self.hi:
            return True
        if v == self.lo and not self.lo_open:
            return True
        if v == self.hi and not self.hi_open:
            return True
        return False

    @property
    def attains_zero(self) -> bool:
        return self.attains(0.0)

    @property
    def attains_pinf(self) -> bool:
        return self.hi == _INF and not self.hi_open

    @property
    def attains_ninf(self) -> bool:
        return self.lo == -_INF and not self.lo_open

    @property
    def attains_inf(self) -> bool:
        return self.attains_pinf or self.attains_ninf

    @property
    def is_nonneg(self) -> bool:
        return self.lo > 0 or (self.lo == 0 and True)

    def contains_negative(self) -> bool:
        return self.lo < 0

    def __str__(self) -> str:  # compact, for finding messages
        l, r = "([" [not self.lo_open], ")]" [not self.hi_open]
        nan = "+nan" if self.maybe_nan else ""
        return f"{l}{self.lo:g}, {self.hi:g}{r}{nan}"

    # ---------------- lattice ----------------

    def hull(self, other: "Interval") -> "Interval":
        """Smallest interval containing both (set union over-approximation)."""
        if self.lo < other.lo:
            lo, lo_open = self.lo, self.lo_open
        elif other.lo < self.lo:
            lo, lo_open = other.lo, other.lo_open
        else:
            lo, lo_open = self.lo, self.lo_open and other.lo_open
        if self.hi > other.hi:
            hi, hi_open = self.hi, self.hi_open
        elif other.hi > self.hi:
            hi, hi_open = other.hi, other.hi_open
        else:
            hi, hi_open = self.hi, self.hi_open and other.hi_open
        return Interval(lo, hi, lo_open, hi_open,
                        self.maybe_nan or other.maybe_nan)

    def intersect(self, other: "Interval") -> "Interval":
        """Set intersection (used by branch refinement).  An empty
        intersection collapses to the refining interval — conservative but
        keeps downstream math defined."""
        if other.lo > self.lo or (other.lo == self.lo and other.lo_open):
            lo, lo_open = other.lo, other.lo_open
        else:
            lo, lo_open = self.lo, self.lo_open
        if other.hi < self.hi or (other.hi == self.hi and other.hi_open):
            hi, hi_open = other.hi, other.hi_open
        else:
            hi, hi_open = self.hi, self.hi_open
        if lo > hi:
            return other
        return Interval(lo, hi, lo_open, hi_open, self.maybe_nan)

    def widen_against(self, newer: "Interval") -> "Interval":
        """Fixpoint widening: any endpoint that moved goes to its infinity."""
        lo, lo_open = self.lo, self.lo_open
        hi, hi_open = self.hi, self.hi_open
        if newer.lo < lo:
            lo, lo_open = -_INF, True
        if newer.hi > hi:
            hi, hi_open = _INF, True
        # endpoint attainability can also grow (closed beats open)
        if newer.lo == lo and not newer.lo_open:
            lo_open = False
        if newer.hi == hi and not newer.hi_open:
            hi_open = False
        return Interval(lo, hi, lo_open, hi_open,
                        self.maybe_nan or newer.maybe_nan)

    def __eq__(self, other) -> bool:
        if not isinstance(other, Interval):
            return NotImplemented
        return (self.lo == other.lo and self.hi == other.hi
                and self.lo_open == other.lo_open
                and self.hi_open == other.hi_open
                and self.maybe_nan == other.maybe_nan)

    # ---------------- arithmetic ----------------

    def neg(self) -> "Interval":
        return Interval(-self.hi, -self.lo, self.hi_open, self.lo_open,
                        self.maybe_nan)

    def add(self, o: "Interval") -> "Interval":
        nan = (self.maybe_nan or o.maybe_nan
               or (self.attains_pinf and o.attains_ninf)
               or (self.attains_ninf and o.attains_pinf))
        lo = self.lo + o.lo
        if math.isnan(lo):          # -inf + inf endpoint pairing
            lo = -_INF
        hi = self.hi + o.hi
        if math.isnan(hi):
            hi = _INF
        return Interval(lo, hi,
                        self.lo_open or o.lo_open,
                        self.hi_open or o.hi_open, nan)

    def sub(self, o: "Interval") -> "Interval":
        return self.add(o.neg())

    def _sign_parts(self):
        """Split into sign-homogeneous subintervals ('+': ⊆ [0, inf],
        '-': ⊆ [-inf, 0]); 0 straddled in the interior is attained."""
        if self.lo >= 0:
            return [("+", self)]
        if self.hi <= 0:
            return [("-", self)]
        return [
            ("-", Interval(self.lo, 0.0, self.lo_open, False)),
            ("+", Interval(0.0, self.hi, False, self.hi_open)),
        ]

    @staticmethod
    def _mul_nonneg(a: "Interval", b: "Interval") -> "Interval":
        """Product of two intervals ⊆ [0, +inf].  Matched-endpoint products
        avoid the spurious 0 x inf corner of the naive all-pairs rule."""
        lo = a.lo * b.lo
        if math.isnan(lo):              # [inf, inf] x an interval attaining 0
            lo, lo_open = 0.0, True
        elif lo == 0.0:
            # 0 attained iff whichever operand supplies the zero attains it
            if a.lo == 0.0 and b.lo == 0.0:
                lo_open = a.lo_open and b.lo_open
            elif a.lo == 0.0:
                lo_open = a.lo_open
            else:
                lo_open = b.lo_open
        else:
            lo_open = a.lo_open or b.lo_open
        if a.hi == _INF or b.hi == _INF:
            attained = (a.attains_pinf and b.hi > 0.0) or \
                       (b.attains_pinf and a.hi > 0.0)
            hi, hi_open = _INF, not attained
        else:
            hi = a.hi * b.hi
            hi_open = a.hi_open or b.hi_open
        return Interval(lo, hi, lo_open, hi_open)

    def mul(self, o: "Interval") -> "Interval":
        nan = (self.maybe_nan or o.maybe_nan
               or (self.attains_inf and o.attains_zero)
               or (self.attains_zero and o.attains_inf))
        res: "Interval | None" = None
        for sa, ia in self._sign_parts():
            for sb, ib in o._sign_parts():
                pa = ia if sa == "+" else ia.neg()
                pb = ib if sb == "+" else ib.neg()
                p = self._mul_nonneg(pa, pb)
                if sa != sb:
                    p = p.neg()
                res = p if res is None else res.hull(p)
        assert res is not None
        return Interval(res.lo, res.hi, res.lo_open, res.hi_open, nan)

    def div(self, o: "Interval") -> "Interval":
        nan = (self.maybe_nan or o.maybe_nan
               or (self.attains_zero and o.attains_zero)
               or (self.attains_inf and o.attains_inf))
        if o.attains_zero:
            # an actual division by zero produces an actual infinity
            return Interval(-_INF, _INF, False, False, nan)
        if o.attains(0.0) is False and (o.lo < 0 < o.hi):
            # denominator straddles 0 only through open endpoints — results
            # are unbounded both ways but inf itself is never attained
            return Interval(-_INF, _INF, True, True, nan)
        inv = o._reciprocal()
        return self.mul(replace(inv, maybe_nan=False)) if not nan else \
            replace(self.mul(inv), maybe_nan=True)

    def _reciprocal(self) -> "Interval":
        # assumes 0 is not attained; endpoints map to reciprocals, an open
        # zero endpoint maps to an open infinity
        def rec(v, is_open):
            if v == 0.0:
                return _INF, True
            if v == _INF or v == -_INF:
                return 0.0, True
            return 1.0 / v, is_open

        a, ao = rec(self.lo, self.lo_open)
        b, bo = rec(self.hi, self.hi_open)
        # sign conventions: 1/(lo,hi) for same-sign intervals swaps ends
        if self.lo > 0 or (self.lo == 0):
            return Interval(b, a, bo, ao, self.maybe_nan)
        if self.hi < 0 or (self.hi == 0):
            return Interval(b, a, bo, ao, self.maybe_nan)
        return Interval(-_INF, _INF, True, True, self.maybe_nan)

    def min_(self, o: "Interval") -> "Interval":
        if self.lo < o.lo:
            lo, lo_open = self.lo, self.lo_open
        elif o.lo < self.lo:
            lo, lo_open = o.lo, o.lo_open
        else:
            lo, lo_open = self.lo, self.lo_open and o.lo_open
        if self.hi < o.hi:
            hi, hi_open = self.hi, self.hi_open
        elif o.hi < self.hi:
            hi, hi_open = o.hi, o.hi_open
        else:
            hi, hi_open = self.hi, self.hi_open or o.hi_open
        return Interval(lo, hi, lo_open, hi_open,
                        self.maybe_nan or o.maybe_nan)

    def max_(self, o: "Interval") -> "Interval":
        return self.neg().min_(o.neg()).neg()

    def abs_(self) -> "Interval":
        if self.lo >= 0:
            return self
        if self.hi <= 0:
            return self.neg()
        neg, pos = self.neg(), self
        hi = max(neg.hi, pos.hi)
        hi_open = all(i.hi_open for i in (neg, pos) if i.hi == hi)
        return Interval(0.0, hi, False, hi_open, self.maybe_nan)

    def monotone(self, fn, *, nan_below: float | None = None,
                 nan_at: float | None = None) -> "Interval":
        """Apply a monotonically increasing scalar function to both ends.

        ``nan_below``: arguments < that value produce NaN (e.g. ``log`` and
        negatives); ``nan_at``: that attained argument produces ±inf
        (``log`` at 0).  Endpoint results of ``±inf`` inherit openness from
        whether the dangerous argument is attained.
        """
        nan = self.maybe_nan or (nan_below is not None and self.lo < nan_below)

        def app(v, is_open):
            try:
                r = fn(v)
            except (ValueError, OverflowError):
                return (-_INF, True) if v < 0 or v < (nan_below or 0) \
                    else (_INF, True)
            if math.isnan(r):
                return -_INF, True
            return r, is_open

        lo, lo_open = app(self.lo, self.lo_open)
        hi, hi_open = app(self.hi, self.hi_open)
        if nan_at is not None and self.attains(nan_at):
            # e.g. log at an attained 0: the -inf endpoint is attained
            lo, lo_open = min(lo, -_INF), False
        return Interval(min(lo, hi), max(lo, hi),
                        lo_open if lo <= hi else hi_open,
                        hi_open if lo <= hi else lo_open, nan)

    def round_like(self, mode: str) -> "Interval":
        """floor / ceil / round / trunc: endpoints round, set stays bounded
        by the rounded endpoints; finite endpoints become attainable."""
        f = {"floor": math.floor, "ceil": math.ceil,
             "round": round, "trunc": math.trunc}[mode]

        def app(v, is_open):
            if v in (-_INF, _INF):
                return v, is_open
            return float(f(v)), False
        lo, lo_open = app(self.lo, self.lo_open)
        hi, hi_open = app(self.hi, self.hi_open)
        return Interval(lo, hi, lo_open, hi_open, self.maybe_nan)

    def scale_by_count(self, n: int) -> "Interval":
        """Over-approximation of an ``n``-term reduction (sum/cumsum): the
        hull of ``k * x`` for ``k`` in 0..n over this per-element interval."""
        acc = Interval.point(0.0)
        per = self.mul(Interval(0.0, float(max(n, 0))))
        return acc.hull(per)


#: any finite value, sign unknown — the default for unknown primitives
FINITE_TOP = Interval(-_INF, _INF, True, True)
#: any value including attained infinities
TOP = Interval(-_INF, _INF, False, False)
#: physical nonnegative quantity, unbounded but finite
NONNEG = Interval(0.0, _INF, False, True)
#: a fraction in [0, 1]
UNIT = Interval(0.0, 1.0)
#: a boolean (comparisons, logical ops)
BOOL = Interval(0.0, 1.0)
