"""grad-blocker: zero-derivative primitives on a differentiated path.

``floor``/``ceil``/``round``, float->int casts, and ``stop_gradient``
silently kill gradients: calibration and gradient search see flat
objectives with no error.  This checker walks only the targets that are
actually differentiated (``grad_mode=True``: the ``j_totalCost`` path of
``grad_objective``, the calibration loss, the tuner's relaxed objective)
and flags those primitives — **unless** they are routed through the
``ste_*`` helpers in :mod:`repro.core.hadoop.merge_math`, which trace as
``custom_jvp_call`` (the author owns the gradient there, so interiors are
exempt on principle), or applied to validity flags (values derived purely
from comparisons, which carry no useful gradient anyway).
"""

from __future__ import annotations

from ..findings import Finding
from .nan_hazard import format_events

__all__ = ["run", "EVENT_KINDS"]

EVENT_KINDS = {
    "rounding": "floor/ceil/round has zero derivative",
    "int_cast": "float -> integer cast has zero derivative",
    "stop_gradient": "stop_gradient severs the path",
}

_HINT = (
    "route round counts through merge_math.ste_floor / ste_ceil / ste_round "
    "(straight-through custom_jvp), or keep the op off the differentiated "
    "path; stop_gradient is fine on validity flags only"
)


def run(ctx) -> list[Finding]:
    findings: list[Finding] = []
    for t in ctx.targets:
        if not t.traceable or not t.grad_mode:
            continue
        an = ctx.analyzed(t)
        findings.extend(
            format_events(an, t.name, "grad-blocker", EVENT_KINDS, _HINT))
    return findings
