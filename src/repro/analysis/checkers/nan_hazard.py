"""nan-hazard: divisions / logs / subtractions that can produce NaN under
the declared axis bounds.

The exact PR-6 bug class: a masked computation whose *forward* value is a
deliberate ``inf`` but whose unguarded primitive can evaluate ``0/0``,
``inf - inf``, ``0 * inf`` or ``log(0)`` — either in the primal or as a
``0 * inf`` cotangent.  Detected by abstract-interval propagation
(:mod:`repro.analysis.absint`) with :func:`repro.spec.hadoop_space` bounds
as the initial abstraction; a double-``where`` guard refines the guarded
operand's interval away from the singularity, which is how guarded sites
pass without any pattern-matching on the guard idiom.
"""

from __future__ import annotations

from ..findings import Finding

__all__ = ["run", "EVENT_KINDS", "format_events"]

EVENT_KINDS = {
    "div0": "denominator can be exactly 0 under the axis bounds",
    "inf_over_inf": "numerator and denominator can both be infinite",
    "inf_minus_inf": "both operands can carry the same-signed infinity",
    "zero_times_inf": "one factor can be 0 while the other is infinite",
    "log_domain": "argument can reach log's singular domain (<= 0)",
    "sqrt_domain": "argument can be negative",
    "pow_domain": "negative base with non-integer exponent",
}

_HINT = (
    "guard with the double-where idiom: "
    "where(ok, f(where(ok, x, safe)), masked) — see "
    "repro.core.hadoop.model._masked_div"
)


def format_events(analysis, target_name: str, checker: str,
                  kinds: dict[str, str], hint: str) -> list[Finding]:
    from ..absint import format_frame

    out = []
    for e in analysis.events:
        if e.kind not in kinds:
            continue
        out.append(Finding(
            checker=checker,
            target=target_name,
            kind=e.kind,
            message=f"{kinds[e.kind]}: {e.detail}",
            location=format_frame(e.frame),
            chain=e.chain,
            hint=hint,
        ))
    return out


def run(ctx) -> list[Finding]:
    findings: list[Finding] = []
    for t in ctx.targets:
        if not t.traceable:
            continue
        an = ctx.analyzed(t)
        findings.extend(
            format_events(an, t.name, "nan-hazard", EVENT_KINDS, _HINT))
    return findings
