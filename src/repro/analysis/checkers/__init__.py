"""Checker registry for :mod:`repro.analysis`.

Each checker module exposes ``run(ctx) -> list[Finding]`` where ``ctx`` is
an :class:`AnalysisContext` carrying lazily-traced targets.  The registry
order is the report order; checker names are frozen in
``repro/spec/manifest.json``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from ..absint import Analysis, analyze_jaxpr
from ..targets import TraceTarget, iter_targets, trace_target

__all__ = ["CHECKERS", "AnalysisContext"]


@dataclass
class AnalysisContext:
    """Shared state for one analysis run: targets are traced (and abstractly
    interpreted) once, then reused by every jaxpr-level checker."""

    targets: list[TraceTarget] = field(default_factory=iter_targets)
    _traced: dict = field(default_factory=dict)
    _analyzed: dict = field(default_factory=dict)

    def traced(self, t: TraceTarget):
        if t.name not in self._traced:
            self._traced[t.name] = trace_target(t)
        return self._traced[t.name]

    def analyzed(self, t: TraceTarget) -> Analysis:
        if t.name not in self._analyzed:
            closed, intervals, _names = self.traced(t)
            self._analyzed[t.name] = analyze_jaxpr(
                closed, intervals, grad_mode=t.grad_mode)
        return self._analyzed[t.name]


def _registry():
    from . import (grad_blocker, mask_contract, nan_hazard, pallas_kernel,
                   recompile)

    return {
        "nan-hazard": nan_hazard,
        "grad-blocker": grad_blocker,
        "recompile-hazard": recompile,
        "mask-contract": mask_contract,
        "pallas-kernel": pallas_kernel,
    }


CHECKERS = _registry()
