"""pallas-kernel: launch-geometry contracts, checked without a TPU.

A Pallas launch whose block shape does not divide its operand dims, whose
``index_map`` arity disagrees with the grid, or whose kernel signature does
not match ``in_specs + outputs + scratch`` fails at Mosaic compile time on
real hardware — which CI (CPU-only) never reaches.  This checker intercepts
``pl.pallas_call`` with a recording stub that validates the launch geometry
and returns zeros of ``out_shape``, then invokes each registered kernel
wrapper on its canonical shapes.  Nothing compiles, nothing runs on device:
the wrapper body executes eagerly against the stub.

Validated per launch (see :func:`validate_launch` for the rule list):
block divisibility, spec/operand arity, index-map arity vs grid,
kernel-ref arity, and ``dimension_semantics`` length vs grid.
"""

from __future__ import annotations

import inspect

from ..findings import Finding

__all__ = ["run", "validate_launch", "probe_kernels", "KERNEL_PROBES"]

_HINT = (
    "pad operands to block multiples in ops.py (_pad_to) or pick block "
    "shapes that divide the padded dims; index_map takes one argument per "
    "grid axis"
)


def _required_arity(fn) -> int:
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return -1
    n = 0
    for p in sig.parameters.values():
        if p.kind in (p.VAR_POSITIONAL, p.VAR_KEYWORD):
            return -1                   # *args — arity unchecked
        if p.default is p.empty and p.kind in (
                p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
            n += 1
    return n


def _block_shape(spec):
    return getattr(spec, "block_shape", None)


def _index_map(spec):
    return getattr(spec, "index_map", None)


def validate_launch(*, name, kernel, grid, in_specs, out_specs, out_shape,
                    scratch_shapes, compiler_params, operands,
                    location) -> list[Finding]:
    """All geometry findings for one recorded ``pallas_call`` launch."""
    findings: list[Finding] = []

    def add(kind, message):
        findings.append(Finding(
            checker="pallas-kernel", target=name, kind=kind,
            message=message, location=location, hint=_HINT))

    grid = tuple(grid) if not isinstance(grid, int) else (grid,)
    out_specs_l = out_specs if isinstance(out_specs, (list, tuple)) \
        else [out_specs]
    out_shapes_l = out_shape if isinstance(out_shape, (list, tuple)) \
        else [out_shape]

    if len(in_specs) != len(operands):
        add("spec_arity",
            f"{len(in_specs)} in_specs for {len(operands)} operands")

    def check_block(spec, shape, what):
        bs = _block_shape(spec)
        if bs is None:
            return
        if len(bs) != len(shape):
            add("block_rank",
                f"{what}: block_shape rank {len(bs)} vs operand rank "
                f"{len(shape)} (block {tuple(bs)}, operand {tuple(shape)})")
            return
        for d, (b, s) in enumerate(zip(bs, shape)):
            if isinstance(b, int) and s % b != 0:
                add("block_divisibility",
                    f"{what}: block dim {d} is {b} but operand dim is {s} "
                    f"({s} % {b} = {s % b}) — Mosaic pads or rejects this")

    for i, (spec, op) in enumerate(zip(in_specs, operands)):
        check_block(spec, op.shape, f"in_specs[{i}]")
    for i, (spec, sh) in enumerate(zip(out_specs_l, out_shapes_l)):
        check_block(spec, sh.shape, f"out_specs[{i}]")

    for i, spec in enumerate(list(in_specs) + list(out_specs_l)):
        im = _index_map(spec)
        if im is None:
            continue
        ar = _required_arity(im)
        if ar >= 0 and ar != len(grid):
            what = f"in_specs[{i}]" if i < len(in_specs) \
                else f"out_specs[{i - len(in_specs)}]"
            add("index_map_arity",
                f"{what}: index_map takes {ar} args for a {len(grid)}-d grid")

    n_refs = len(in_specs) + len(out_shapes_l) + len(scratch_shapes or ())
    ar = _required_arity(kernel)
    if ar >= 0 and ar != n_refs:
        add("kernel_arity",
            f"kernel takes {ar} refs but launch provides {n_refs} "
            f"({len(in_specs)} in + {len(out_shapes_l)} out + "
            f"{len(scratch_shapes or ())} scratch)")

    sem = getattr(compiler_params, "dimension_semantics", None)
    if sem is not None and len(sem) != len(grid):
        add("dimension_semantics",
            f"dimension_semantics has {len(sem)} entries for a "
            f"{len(grid)}-d grid")
    return findings


class _Recorder:
    """Stand-in for ``pl.pallas_call``: validates geometry, returns zeros."""

    def __init__(self):
        self.findings: list[Finding] = []
        self.launches = 0

    def __call__(self, kernel, *, grid, in_specs, out_specs, out_shape,
                 scratch_shapes=None, compiler_params=None,
                 interpret=False, name="<unnamed>", **_kw):
        def apply(*operands):
            import jax.numpy as jnp

            self.launches += 1
            self.findings.extend(validate_launch(
                name=name, kernel=kernel, grid=grid, in_specs=in_specs,
                out_specs=out_specs, out_shape=out_shape,
                scratch_shapes=scratch_shapes,
                compiler_params=compiler_params, operands=operands,
                location=f"pallas_call name={name!r}"))
            outs = out_shape if isinstance(out_shape, (list, tuple)) \
                else [out_shape]
            zeros = [jnp.zeros(o.shape, o.dtype) for o in outs]
            return zeros if isinstance(out_shape, (list, tuple)) \
                else zeros[0]

        return apply


def _probe_flash():
    import jax.numpy as jnp

    from repro.kernels.flash_attention import flash_attention_pallas

    q = jnp.zeros((1, 2, 256, 128), jnp.float32)
    k = jnp.zeros((1, 1, 256, 128), jnp.float32)
    flash_attention_pallas(q, k, k, causal=True, window=100, logit_cap=50.0)


def _probe_decode():
    import jax.numpy as jnp

    from repro.kernels.decode_attention import decode_attention_pallas

    q = jnp.zeros((1, 2, 2, 128), jnp.float32)
    kc = jnp.zeros((1, 2, 512, 128), jnp.float32)
    slot = jnp.arange(512, dtype=jnp.int32)
    decode_attention_pallas(q, kc, kc, slot, jnp.int32(511), window=128)


def _probe_seg_combine():
    import jax.numpy as jnp

    from repro.kernels.seg_combine import seg_combine_pallas

    vals = jnp.zeros((1024, 256), jnp.float32)
    pids = jnp.zeros((1024,), jnp.int32)
    seg_combine_pallas(vals, pids, 8)


#: canonical launch per registered kernel — the shapes ops.py pads to.
KERNEL_PROBES = {
    "flash_attention": _probe_flash,
    "decode_attention": _probe_decode,
    "seg_combine": _probe_seg_combine,
}


def probe_kernels(probes=None) -> list[Finding]:
    """Run ``probes`` under the recording stub; return geometry findings."""
    from jax.experimental import pallas as pl

    rec = _Recorder()
    original = pl.pallas_call
    pl.pallas_call = rec
    try:
        for name, probe in (probes or KERNEL_PROBES).items():
            try:
                probe()
            except Exception as e:          # geometry asserts in the wrapper
                rec.findings.append(Finding(
                    checker="pallas-kernel", target=name,
                    kind="wrapper_error",
                    message=f"kernel wrapper raised {type(e).__name__}: {e}",
                    location=f"probe {name}", hint=_HINT))
    finally:
        pl.pallas_call = original
    return rec.findings


def run(ctx) -> list[Finding]:
    del ctx  # kernel probes need no traced targets
    return probe_kernels()
