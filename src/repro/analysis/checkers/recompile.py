"""recompile-hazard: trace instability that breaks one-compile-per-key-set.

:class:`repro.search.evaluator.ChunkedEvaluator`'s contract is ONE compile
per override key-set: padded fixed-shape chunks mean any grid size reuses
the same executable.  Three things silently break it:

* **weak-type leakage** — a Python scalar reaching the traced signature
  gives a ``weak_type=True`` aval; the same call with a strong-typed array
  is a different compile key, and promotion flips dtypes downstream.
* **python-scalar outputs** — a weak-typed jaxpr *output* re-promotes in
  consumers, changing their compile keys per call site.
* **shape/value-dependent control flow** — Python ``if``/``for`` on traced
  shapes re-traces to a structurally different jaxpr when the padded block
  changes, so every distinct grid recompiles (the contract's one compile
  becomes O(grids)).

The first two are read off the traced jaxpr avals.  The third is probed
*statically* by tracing the evaluator body twice — same key-set, different
values and row counts (padded) — and comparing the jaxprs: tracing is
abstract evaluation, nothing runs on device.
"""

from __future__ import annotations

from ..findings import Finding

__all__ = ["run", "probe_trace_stability", "weak_type_findings"]

_HINT_WEAK = (
    "wrap Python scalars with jnp.asarray(..., dtype=...) at the boundary "
    "(split_overrides does this for evaluator columns)"
)
_HINT_RETRACE = (
    "make the body a function of static shapes only: pad to fixed chunk "
    "shapes (pad_block) and branch with lax.cond/jnp.where, not Python "
    "control flow on traced values"
)


def weak_type_findings(closed, target_name: str) -> list[Finding]:
    """Weak-typed invars / outvars of a traced target."""
    out: list[Finding] = []
    jaxpr = closed.jaxpr
    n_weak_in = sum(
        1 for v in jaxpr.invars if getattr(v.aval, "weak_type", False))
    if n_weak_in:
        out.append(Finding(
            checker="recompile-hazard",
            target=target_name,
            kind="weak_type_input",
            message=(f"{n_weak_in} traced input(s) carry weak_type=True — "
                     "a Python scalar reached the trace boundary"),
            location=f"{target_name} signature in trace",
            hint=_HINT_WEAK,
        ))
    n_weak_out = sum(
        1 for v in jaxpr.outvars
        if getattr(getattr(v, "aval", None), "weak_type", False))
    if n_weak_out:
        out.append(Finding(
            checker="recompile-hazard",
            target=target_name,
            kind="weak_type_output",
            message=(f"{n_weak_out} jaxpr output(s) are weak-typed — "
                     "consumers will re-promote (and re-compile) per dtype"),
            location=f"{target_name} outputs in trace",
            hint=_HINT_WEAK,
        ))
    return out


def probe_trace_stability(fn, args_a, args_b, *, target_name: str,
                          location: str) -> list[Finding]:
    """Trace ``fn`` on two same-key-set argument sets; different jaxprs
    mean the compile cache misses whenever the data changes."""
    import jax

    try:
        ja = jax.make_jaxpr(fn)(*args_a)
        jb = jax.make_jaxpr(fn)(*args_b)
    except Exception as e:  # value-dependent Python branch on a tracer
        return [Finding(
            checker="recompile-hazard",
            target=target_name,
            kind="trace_error",
            message=f"tracing raised {type(e).__name__}: {e}",
            location=location,
            hint=_HINT_RETRACE,
        )]
    sa, sb = str(ja), str(jb)
    if sa != sb:
        import difflib
        diff = [ln for ln in difflib.unified_diff(
            sa.splitlines(), sb.splitlines(), lineterm="", n=0)
            if ln.startswith(("+", "-")) and not ln.startswith(("+++", "---"))]
        return [Finding(
            checker="recompile-hazard",
            target=target_name,
            kind="retrace",
            message=("same key-set, different jaxpr (" +
                     f"{len(diff)} line(s) differ; first: "
                     f"{diff[0][:120] if diff else '?'}) — every distinct "
                     "grid/block recompiles"),
            location=location,
            hint=_HINT_RETRACE,
        )]
    in_a = [str(v.aval) for v in ja.jaxpr.invars]
    in_b = [str(v.aval) for v in jb.jaxpr.invars]
    if in_a != in_b:
        return [Finding(
            checker="recompile-hazard",
            target=target_name,
            kind="signature_drift",
            message="same key-set, different input avals — compile key "
                    f"changed: {in_a} vs {in_b}",
            location=location,
            hint=_HINT_WEAK,
        )]
    return []


def _chunked_evaluator_probe() -> list[Finding]:
    import numpy as np

    from repro.core.hadoop.params import (CostFactors, HadoopParams,
                                          ProfileStats)
    from repro.search.evaluator import ChunkedEvaluator, pad_block

    ev = ChunkedEvaluator(HadoopParams(), ProfileStats(), CostFactors(),
                          chunk=8)
    body = ev._sharded_body()

    def blocks(values):
        batched = {"pSortMB": np.asarray(values, dtype=np.float64)}
        cols, _mask = pad_block(batched, 0, len(values), ev.chunk)
        cols = {k: np.asarray(v) for k, v in cols.items()}
        return (cols, dict(ev.base_cfg))

    # same key-set {pSortMB}: different values AND different pre-pad length
    a = blocks([100.0, 200.0, 300.0])
    b = blocks([50.0] * 7)
    return probe_trace_stability(
        body, a, b,
        target_name="chunked-evaluator",
        location="src/repro/search/evaluator.py in _sharded_body")


def run(ctx) -> list[Finding]:
    findings: list[Finding] = []
    for t in ctx.targets:
        if not t.traceable:
            continue
        closed, _intervals, _names = ctx.traced(t)
        findings.extend(weak_type_findings(closed, t.name))
    findings.extend(_chunked_evaluator_probe())
    return findings
