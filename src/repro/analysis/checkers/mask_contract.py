"""mask-contract: cost totals must flow through the shared masking helpers.

The PR-2 silent-``inf`` class: an evaluator (or strategy) that reads raw
model costs without ``sanitize_costs``/``masked_total`` lets NaN/inf rows
win or poison reductions, and validity flags consumed row-by-row before
being combined defeat the ``valid == 0`` escape hatch.  Two rules:

* **AST rule** — every ``Evaluator`` subclass's ``evaluate`` (and any
  function constructing a ``SearchResult`` with ``total_cost=``) must call
  ``masked_total`` or ``sanitize_costs``, or delegate to another
  ``evaluate``.  Purely-abstract bodies (``raise NotImplementedError``) are
  exempt.
* **jaxpr rule** — every traced *model* target must emit a validity output
  (``valid`` / ``converged``) alongside its costs; a model whose cost can
  be ``inf``/NaN with no flag to mask on cannot honor the contract at all.

The AST rule runs over the real source tree (and over fixture source in
the analyzer's own tests via :func:`check_source`).
"""

from __future__ import annotations

import ast
import os

from ..findings import Finding

__all__ = ["run", "check_source", "iter_source_files"]

_MASK_HELPERS = ("masked_total", "sanitize_costs")
_VALIDITY_NAMES = ("valid", "converged")
_HINT = (
    "route totals through repro.search.evaluator.masked_total (or sanitize "
    "raw costs with sanitize_costs) and emit a validity flag the caller can "
    "mask on"
)


def _calls_in(node: ast.AST) -> set[str]:
    names = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            f = n.func
            if isinstance(f, ast.Name):
                names.add(f.id)
            elif isinstance(f, ast.Attribute):
                names.add(f.attr)
    return names


def _is_abstract(fn: ast.FunctionDef) -> bool:
    for n in ast.walk(fn):
        if isinstance(n, ast.Raise):
            exc = n.exc
            name = ""
            if isinstance(exc, ast.Call) and isinstance(exc.func, ast.Name):
                name = exc.func.id
            elif isinstance(exc, ast.Name):
                name = exc.id
            if name == "NotImplementedError":
                return True
    return False


def _builds_search_result(fn: ast.FunctionDef) -> bool:
    for n in ast.walk(fn):
        if isinstance(n, ast.Call):
            f = n.func
            nm = f.id if isinstance(f, ast.Name) else (
                f.attr if isinstance(f, ast.Attribute) else "")
            if nm == "SearchResult" and any(
                    kw.arg == "total_cost" for kw in n.keywords):
                return True
    return False


def check_source(text: str, filename: str) -> list[Finding]:
    """AST rule over one file's source text."""
    try:
        tree = ast.parse(text)
    except SyntaxError:
        return []
    findings: list[Finding] = []

    def check_fn(fn: ast.FunctionDef, owner: str):
        if _is_abstract(fn):
            return
        calls = _calls_in(fn)
        if any(h in calls for h in _MASK_HELPERS):
            return
        if "evaluate" in calls or "evaluate_small" in calls:
            return          # delegates to another evaluate implementation
        findings.append(Finding(
            checker="mask-contract",
            target=owner,
            kind="unmasked_total",
            message=(f"{owner}.{fn.name} produces a cost total without "
                     "masked_total/sanitize_costs — NaN/inf rows flow to "
                     "callers unmasked"),
            location=f"{filename}:{fn.lineno} in {fn.name}",
            hint=_HINT,
        ))

    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and (
                "Evaluator" in node.name
                or any("Evaluator" in getattr(b, "id", getattr(b, "attr", ""))
                       for b in node.bases)):
            for item in node.body:
                if isinstance(item, ast.FunctionDef) \
                        and item.name == "evaluate":
                    check_fn(item, node.name)
        elif isinstance(node, ast.FunctionDef) and _builds_search_result(node):
            # module-level / nested functions constructing results directly
            in_class = False  # handled above when inside Evaluator classes
            for cls in ast.walk(tree):
                if isinstance(cls, ast.ClassDef) and node in ast.walk(cls):
                    in_class = True
                    break
            if not in_class:
                check_fn(node, filename.rsplit("/", 1)[-1])
    return findings


def _repro_root() -> str:
    import repro

    # namespace package: no __file__, locate via __path__
    return os.path.abspath(list(repro.__path__)[0])


def iter_source_files() -> list[str]:
    root = _repro_root()
    out = []
    for dirpath, _dirnames, filenames in os.walk(root):
        if "analysis" in os.path.relpath(dirpath, root).split(os.sep):
            continue                     # the analyzer does not self-apply
        for f in sorted(filenames):
            if f.endswith(".py"):
                out.append(os.path.join(dirpath, f))
    return out


def _validity_output_findings(ctx) -> list[Finding]:
    findings = []
    for t in ctx.targets:
        if not t.traceable or t.grad_mode:
            continue                     # grad targets return bare scalars
        _closed, _intervals, names = ctx.traced(t)
        if not any(v in names for v in _VALIDITY_NAMES):
            findings.append(Finding(
                checker="mask-contract",
                target=t.name,
                kind="no_validity_output",
                message=("model emits no validity flag "
                         f"({'/'.join(_VALIDITY_NAMES)}) — masked-inf costs "
                         "cannot be distinguished from real ones"),
                location=f"{t.name} outputs in trace",
                hint=_HINT,
            ))
    return findings


def run(ctx) -> list[Finding]:
    findings: list[Finding] = []
    src_root = os.path.dirname(os.path.dirname(_repro_root()))
    for path in iter_source_files():
        with open(path) as f:
            text = f.read()
        rel = os.path.relpath(path, src_root)
        findings.extend(check_source(text, rel))
    findings.extend(_validity_output_findings(ctx))
    return findings
