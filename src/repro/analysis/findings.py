"""Finding / report / baseline plumbing for :mod:`repro.analysis`.

A :class:`Finding` is one checker hit on one target; its
:meth:`~Finding.fingerprint` is the stable identity used by the baseline
file (``analysis_baseline.json``), which freezes *accepted* findings the
same way ``repro/spec/manifest.json`` freezes the API surface.  The
fingerprint deliberately excludes line numbers — accepted findings should
survive unrelated edits — but includes file basename, function, checker,
kind, and primitive, so a finding that moves to different code re-fires.
"""

from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass, field

__all__ = ["Finding", "Report", "load_baseline", "save_baseline",
           "DEFAULT_BASELINE"]

#: repo-root relative default baseline location
DEFAULT_BASELINE = "analysis_baseline.json"

#: the frozen finding schema (mirrored in repro/spec/manifest.json and
#: guarded by tests/test_api_surface.py)
FINDING_FIELDS = ("checker", "target", "kind", "message", "location",
                  "chain", "hint")


@dataclass(frozen=True)
class Finding:
    checker: str                  # e.g. "nan-hazard"
    target: str                   # e.g. "hadoop-model"
    kind: str                     # e.g. "div0"
    message: str                  # interval/AST story
    location: str                 # "path/to/file.py:123 in fn" or "<unknown>"
    chain: tuple[str, ...] = ()   # enclosing higher-order primitive path
    hint: str = ""                # how to fix

    def fingerprint(self) -> str:
        loc = self.location
        fn = loc.rsplit(" in ", 1)[-1] if " in " in loc else "?"
        file_part = loc.split(":", 1)[0]
        base = os.path.basename(file_part) if file_part else "?"
        return "|".join((self.checker, self.target, self.kind, base, fn))

    def to_dict(self) -> dict:
        d = asdict(self)
        d["chain"] = list(self.chain)
        d["fingerprint"] = self.fingerprint()
        return d


@dataclass
class Report:
    findings: list[Finding] = field(default_factory=list)
    skipped: dict[str, str] = field(default_factory=dict)      # target -> why
    coverage_gaps: dict[str, list[str]] = field(default_factory=dict)
    checkers_run: list[str] = field(default_factory=list)

    def new_findings(self, baseline: set[str]) -> list[Finding]:
        return [f for f in self.findings if f.fingerprint() not in baseline]

    def stale_baseline(self, baseline: set[str]) -> list[str]:
        live = {f.fingerprint() for f in self.findings}
        return sorted(fp for fp in baseline if fp not in live)

    def to_dict(self) -> dict:
        return {
            "findings": [f.to_dict() for f in self.findings],
            "skipped": dict(self.skipped),
            "coverage_gaps": {k: sorted(v)
                              for k, v in self.coverage_gaps.items()},
            "checkers_run": list(self.checkers_run),
        }


def load_baseline(path: str) -> set[str]:
    """Accepted-finding fingerprints, or empty when the file is absent."""
    if not os.path.exists(path):
        return set()
    with open(path) as f:
        data = json.load(f)
    return {e["fingerprint"] for e in data.get("accepted", [])}


def save_baseline(path: str, report: Report) -> None:
    """Freeze the report's current findings as the accepted baseline."""
    data = {
        "_comment": (
            "Accepted repro.analysis findings. CI fails on any finding "
            "whose fingerprint is not listed here; update deliberately via "
            "`python -m repro.analysis --update-baseline` and justify each "
            "entry's `reason`."),
        "accepted": [
            {
                "fingerprint": f.fingerprint(),
                "checker": f.checker,
                "target": f.target,
                "kind": f.kind,
                "location": f.location,
                "reason": "TODO: justify why this finding is accepted",
            }
            for f in report.findings
        ],
    }
    with open(path, "w") as fh:
        json.dump(data, fh, indent=2, sort_keys=False)
        fh.write("\n")
