"""CI gate CLI: ``python -m repro.analysis``.

Exit codes: 0 = no findings beyond the baseline, 1 = new findings (or a
broken analyzer in ``--smoke``).  ``--update-baseline`` rewrites the
baseline from the current run — every entry then needs a human-written
``reason``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from . import (DEFAULT_BASELINE, load_baseline, run_all, save_baseline)


def _default_baseline_path() -> str:
    # repo root = two levels above src/repro (src/repro/analysis/..)
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    cand = os.path.join(root, DEFAULT_BASELINE)
    return cand if os.path.isdir(root) else DEFAULT_BASELINE


def _smoke() -> int:
    """Fast self-test: every checker must fire on its known-bad fixture."""
    from .fixtures import selftest

    results = selftest()
    bad = 0
    for name, findings in results.items():
        status = "ok" if findings else "DEAD"
        if not findings:
            bad += 1
        print(f"  {name:20s} {status}  "
              f"({len(findings)} finding(s) on its fixture)")
    if bad:
        print(f"analysis --smoke: {bad} checker(s) no longer fire on their "
              "known-bad fixtures", file=sys.stderr)
        return 1
    print("analysis --smoke: all checkers fire on their fixtures")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis gate over the registered cost models")
    ap.add_argument("--json", action="store_true",
                    help="emit the full machine-readable report on stdout")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help=f"baseline file (default: {DEFAULT_BASELINE} at "
                         "the repo root)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="accept the current findings into the baseline")
    ap.add_argument("--checker", action="append", default=None,
                    metavar="NAME", help="run only the named checker(s)")
    ap.add_argument("--smoke", action="store_true",
                    help="fixture self-test only (fast; no model tracing)")
    args = ap.parse_args(argv)

    if args.smoke:
        return _smoke()

    baseline_path = args.baseline or _default_baseline_path()
    report = run_all(checkers=args.checker)
    baseline = load_baseline(baseline_path)
    new = report.new_findings(baseline)
    stale = report.stale_baseline(baseline)

    if args.update_baseline:
        save_baseline(baseline_path, report)
        print(f"baseline updated: {baseline_path} "
              f"({len(report.findings)} accepted entr(y/ies))")
        return 0

    if args.json:
        payload = report.to_dict()
        payload["new_findings"] = [f.to_dict() for f in new]
        payload["stale_baseline"] = stale
        payload["baseline"] = baseline_path
        json.dump(payload, sys.stdout, indent=2)
        sys.stdout.write("\n")
    else:
        print(f"checkers: {', '.join(report.checkers_run)}")
        for tname, why in report.skipped.items():
            print(f"skipped target {tname}: {why}")
        for tname, prims in report.coverage_gaps.items():
            print(f"coverage gap in {tname}: unmodeled primitives "
                  f"{', '.join(prims)}")
        for f in report.findings:
            mark = "baselined" if f.fingerprint() in baseline else "NEW"
            print(f"[{mark}] {f.checker}/{f.kind} in {f.target} "
                  f"at {f.location}\n    {f.message}")
            if f.hint:
                print(f"    hint: {f.hint}")
        for fp in stale:
            print(f"stale baseline entry (finding no longer fires): {fp}")
        print(f"{len(report.findings)} finding(s), {len(new)} new, "
              f"{len(stale)} stale baseline entr(y/ies)")

    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
