"""Known-bad fixtures: one deliberately-broken model per checker.

These are the analyzer's regression suite — each fixture reproduces the
bug class its checker exists for, so a refactor that blinds a checker
fails ``tests/test_analysis.py`` (and ``python -m repro.analysis --smoke``)
immediately.  None of them ship in any registered model.
"""

from __future__ import annotations

import math

from .interval import FINITE_TOP, Interval
from .targets import TraceTarget

__all__ = ["fixture_targets", "MASK_BAD_SOURCE", "bad_pallas_probes",
           "selftest"]


# --------------------------------------------------------------------------
# jaxpr-level fixtures (traced like real targets)
# --------------------------------------------------------------------------


def _build_nan_fixture():
    """Single-``where`` masked division — the exact pre-PR-6 Eq. 11 bug:
    the *forward* value is fine, but the unguarded ``num / den`` still
    evaluates ``x / 0`` and poisons the cotangent with ``0 * inf``."""
    import jax
    import jax.numpy as jnp

    def f(c):
        ok = c["den"] > 0.0
        out = jnp.where(ok, c["num"] / c["den"], jnp.inf)
        return {"cost": out}

    cfg = {"den": jnp.asarray(2.0), "num": jnp.asarray(3.0)}
    closed = jax.make_jaxpr(f)(cfg)
    # sorted keys: den, num — den's axis bound attains 0
    return closed, [Interval(0.0, math.inf, False, True),
                    Interval(0.0, math.inf, False, True)], ("cost",)


def _build_grad_fixture():
    """Bare ``jnp.floor`` on the differentiated path (should be
    ``merge_math.ste_floor``)."""
    import jax
    import jax.numpy as jnp

    def f(c):
        return {"cost": jnp.floor(c["x"]) * c["x"]}

    closed = jax.make_jaxpr(f)({"x": jnp.asarray(4.0)})
    return closed, [FINITE_TOP], ("cost",)


def _build_recompile_fixture():
    """A Python float crossing the trace boundary: weak-typed input."""
    import jax

    def f(x):
        return {"cost": x + 1.0}

    closed = jax.make_jaxpr(f)(3.0)      # python scalar -> weak_type=True
    return closed, [FINITE_TOP], ("cost",)


def fixture_targets() -> list[TraceTarget]:
    return [
        TraceTarget(
            name="fixture-nan",
            doc="single-where masked division (pre-PR-6 Eq. 11 bug)",
            build=_build_nan_fixture,
        ),
        TraceTarget(
            name="fixture-grad",
            doc="bare jnp.floor on a differentiated path",
            build=_build_grad_fixture,
            grad_mode=True,
        ),
        TraceTarget(
            name="fixture-recompile",
            doc="weak-typed python scalar at the trace boundary",
            build=_build_recompile_fixture,
        ),
    ]


def value_branching_body():
    """For :func:`..checkers.recompile.probe_trace_stability`: a Python
    branch on a *traced value* — the body cannot trace at all (every call
    would need concrete data, defeating one-compile-per-key-set)."""
    import jax.numpy as jnp

    def body(cols):
        x = cols["pSortMB"]
        if x[0] > 4.0:                   # python branch on a traced value
            x = x * 2.0
        return jnp.sum(x)

    return body


# --------------------------------------------------------------------------
# AST fixture for mask-contract
# --------------------------------------------------------------------------

MASK_BAD_SOURCE = '''\
import jax.numpy as jnp


class LeakyEvaluator(Evaluator):
    """Reads raw model costs; inf rows win the argmin."""

    def evaluate(self, overrides):
        out = self.model_fn({**self.base_cfg, **overrides})
        total = out[self.cost_key]              # no masked_total
        best = jnp.argmin(total)
        return SearchResult(total_cost=float(total[best]), best=best)
'''


# --------------------------------------------------------------------------
# pallas fixture: a launch whose block shape does not divide the operand
# --------------------------------------------------------------------------


def _bad_pallas_probe():
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    x = jnp.zeros((4, 1000), jnp.float32)
    pl.pallas_call(
        kernel,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((1, 300), lambda i, j: (i, j))],   # 1000 % 300
        out_specs=pl.BlockSpec((1, 300), lambda i: (i, 0)),       # 1-ary map
        out_shape=jax.ShapeDtypeStruct((4, 1000), jnp.float32),
        name="fixture_bad_block",
    )(x)


def bad_pallas_probes() -> dict:
    return {"fixture-bad-block": _bad_pallas_probe}


# --------------------------------------------------------------------------
# self-test: every checker must fire on its fixture
# --------------------------------------------------------------------------


def selftest() -> dict[str, list]:
    """Run each checker against its known-bad fixture; returns findings per
    checker name.  Every list must be non-empty for a healthy analyzer."""
    from .checkers import CHECKERS, AnalysisContext
    from .checkers import mask_contract, pallas_kernel, recompile

    ctx = AnalysisContext(targets=fixture_targets())
    out: dict[str, list] = {}
    out["nan-hazard"] = CHECKERS["nan-hazard"].run(ctx)
    out["grad-blocker"] = CHECKERS["grad-blocker"].run(ctx)

    weak = []
    for t in ctx.targets:
        closed, _ivals, _names = ctx.traced(t)
        weak.extend(recompile.weak_type_findings(closed, t.name))
    body = value_branching_body()
    import numpy as np

    weak.extend(recompile.probe_trace_stability(
        body,
        ({"pSortMB": np.zeros(8)},),
        ({"pSortMB": np.ones(8)},),
        target_name="fixture-recompile",
        location="fixtures.value_branching_body"))
    out["recompile-hazard"] = weak

    out["mask-contract"] = mask_contract.check_source(
        MASK_BAD_SOURCE, "fixture_evaluator.py")
    out["pallas-kernel"] = pallas_kernel.probe_kernels(
        probes=bad_pallas_probes())
    return out
