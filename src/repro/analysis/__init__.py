"""repro.analysis — jaxpr-level static analysis of the registered models.

Walks the jaxprs of every registered cost model (Hadoop, cluster, the
calibration loss, the gradient-search objectives) under the axis bounds of
:func:`repro.spec.hadoop_space`, plus AST / launch-geometry passes for the
parts no jaxpr reaches.  Five checkers:

==================  ======================================================
``nan-hazard``      div/log/sqrt/sub whose operand intervals reach a
                    singularity (0/0, inf-inf, 0*inf, log 0) without a
                    double-``where`` guard
``grad-blocker``    floor/ceil/round/int-cast/stop_gradient on a path that
                    ``grad_objective``/``calibrate`` differentiates, unless
                    routed through the ``ste_*`` custom_jvp helpers
``recompile-hazard``weak-type promotion, Python-scalar leakage, and
                    trace-unstable bodies that break ChunkedEvaluator's
                    one-compile-per-key-set contract
``mask-contract``   cost totals escaping without ``masked_total``/
                    ``sanitize_costs``; models without validity outputs
``pallas-kernel``   block/grid/index-map/kernel-arity geometry of the
                    Pallas launches, checked without a TPU
==================  ======================================================

Run ``python -m repro.analysis`` for the CI gate (non-zero exit on any
finding not accepted in ``analysis_baseline.json``), or
:func:`run_all` programmatically.
"""

from __future__ import annotations

from .findings import (DEFAULT_BASELINE, FINDING_FIELDS, Finding, Report,
                       load_baseline, save_baseline)
from .interval import Interval
from .targets import TraceTarget, iter_targets

__all__ = [
    "Finding",
    "Report",
    "Interval",
    "TraceTarget",
    "run_all",
    "checker_names",
    "iter_targets",
    "load_baseline",
    "save_baseline",
    "DEFAULT_BASELINE",
    "FINDING_FIELDS",
]


def checker_names() -> list[str]:
    """Registry order == report order; frozen in repro/spec/manifest.json."""
    from .checkers import CHECKERS

    return list(CHECKERS)


def run_all(checkers=None, targets=None) -> Report:
    """Run every (or the named) checker over every registered target.

    ``targets`` overrides the registry — used by the analyzer's own tests
    to point checkers at known-bad fixtures.
    """
    from .checkers import CHECKERS, AnalysisContext

    ctx = AnalysisContext() if targets is None \
        else AnalysisContext(targets=list(targets))
    report = Report()
    for name, mod in CHECKERS.items():
        if checkers is not None and name not in checkers:
            continue
        report.findings.extend(mod.run(ctx))
        report.checkers_run.append(name)
    for t in ctx.targets:
        if not t.traceable:
            report.skipped[t.name] = t.skip_reason
    for tname, an in ctx._analyzed.items():
        if an.unknown_prims:
            report.coverage_gaps[tname] = sorted(an.unknown_prims)
    return report
