"""repro.search — the config-search subsystem.

The paper exists to answer what-if questions and find optimal
configurations.  This package is the platform for that at production scale:

* :mod:`~repro.search.grid`       — streaming Cartesian spaces (no
  materialized 10^6-row products).
* :mod:`~repro.search.evaluator`  — chunked, padded, device-sharded batched
  model evaluation (one XLA compile per key-set; bit-for-bit equal to the
  unchunked path) + the ``valid == 0`` -> exact-simulator escape hatch.
* :mod:`~repro.search.topk`       — streaming on-device top-k merging.
* :mod:`~repro.search.strategies` — grid / random / coordinate-descent /
  gradient-descent search over any evaluator (gradient descent relaxes the
  space continuously and differentiates the model itself, falling back
  loudly on non-differentiable backends).
* :mod:`~repro.search.service`    — async what-if query service: concurrent
  probes/sweeps/grids coalesced into shared evaluator chunks (continuous
  batching over row slots, per-query futures + latency stats).
* :mod:`~repro.search.tpu`        — the TPU step model behind the same
  evaluator interface.

jax version drift (``shard_map`` et al.) is handled by :mod:`repro.compat`.
The seed modules ``repro.core.whatif`` and ``repro.core.tuner`` remain as
thin aliases of this package.
"""

from .evaluator import (
    BlockTopK,
    ChunkedEvaluator,
    Evaluator,
    ExactCostUnavailable,
    InvalidGridError,
    NotDifferentiableError,
    SearchResult,
    apply_assignment,
    cached_evaluator,
    evaluate_unchunked,
    masked_total,
    sanitize_costs,
)
from .grid import assignment_at, iter_blocks, sample_space, space_block, space_size
from .service import PhaseQueryResult, QueryResult, QueryStats, WhatIfService
from .strategies import (
    TuningResult,
    coordinate_descent,
    coordinate_descent_ev,
    gradient_descent_ev,
    grid_search,
    grid_search_ev,
    random_search,
    random_search_ev,
    search_topk,
)
from .topk import TopKAccumulator, TopKEntry, TopKResult
from .tpu import TpuEvaluator, mesh_space, tune_tpu

__all__ = [
    "ExactCostUnavailable",
    "InvalidGridError",
    "NotDifferentiableError",
    "SearchResult",
    "BlockTopK",
    "Evaluator",
    "ChunkedEvaluator",
    "cached_evaluator",
    "evaluate_unchunked",
    "apply_assignment",
    "sanitize_costs",
    "masked_total",
    "space_size",
    "space_block",
    "iter_blocks",
    "sample_space",
    "assignment_at",
    "TopKEntry",
    "TopKResult",
    "TopKAccumulator",
    "TuningResult",
    "search_topk",
    "grid_search",
    "grid_search_ev",
    "random_search",
    "random_search_ev",
    "coordinate_descent",
    "coordinate_descent_ev",
    "gradient_descent_ev",
    "WhatIfService",
    "QueryResult",
    "QueryStats",
    "PhaseQueryResult",
    "TpuEvaluator",
    "mesh_space",
    "tune_tpu",
]
