"""Search strategies over any :class:`repro.search.evaluator.Evaluator`.

The paper's "find the optimal configuration" use case, ported from the seed
``repro.core.tuner`` onto the chunked/sharded evaluator so the same three
strategies drive both the Hadoop job model (:class:`ChunkedEvaluator`) and
the TPU step model (:class:`repro.search.tpu.TpuEvaluator`):

* :func:`search_topk`            — streaming exhaustive top-k over a product
  space (the primitive everything else builds on).
* :func:`grid_search_ev`         — exhaustive optimum (k=1 wrapper).
* :func:`random_search_ev`       — uniform sampling of the space.
* :func:`coordinate_descent_ev`  — per-axis sweeps to a fixpoint.

``grid_search`` / ``random_search`` / ``coordinate_descent`` keep the seed's
Hadoop-first signatures (re-exported by ``repro.core.tuner``).
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from repro.core.hadoop.params import CostFactors, HadoopParams, ProfileStats
from repro.spec.report import invalid_reason_counts

from .evaluator import (
    Evaluator,
    ExactCostUnavailable,
    InvalidGridError,
    apply_assignment,
    cached_evaluator,
)
from .grid import iter_blocks, sample_space
from .topk import TopKAccumulator, TopKResult

__all__ = [
    "TuningResult",
    "search_topk",
    "grid_search_ev",
    "random_search_ev",
    "coordinate_descent_ev",
    "grid_search",
    "random_search",
    "coordinate_descent",
]

logger = logging.getLogger("repro.search.strategies")


@dataclass
class TuningResult:
    best_assignment: dict[str, float]
    best_cost: float
    evaluations: int
    history: list[tuple[dict[str, float], float]] = field(default_factory=list)
    topk: TopKResult | None = None
    exact: bool = False     # best_cost came via the exact-simulator escape hatch

    def apply(self, p: HadoopParams) -> HadoopParams:
        """Materialize the winning assignment onto a HadoopParams object."""
        p2, _, _ = apply_assignment(p, ProfileStats(), CostFactors(),
                                    self.best_assignment)
        return p2


# --------------------------------------------------------------------------
# evaluator-generic strategies
# --------------------------------------------------------------------------


def search_topk(
    evaluator: Evaluator,
    space: Mapping[str, Sequence[float]],
    *,
    k: int = 1,
    exact_fallback: bool = True,
) -> TopKResult:
    """Stream the full Cartesian product through the evaluator in fixed-size
    blocks, reducing each block to its top-k on device and merging on host.

    Invalid (``valid == 0``) survivors of the final ranking are re-costed via
    the evaluator's exact path (simulator) rather than reported as ``inf``.
    """
    t0 = time.perf_counter()
    acc = TopKAccumulator(k)
    for start, cols in iter_blocks(space, evaluator.chunk):
        acc.update(start, cols, evaluator.chunk_topk(cols, k))
    return acc.finalize(
        evaluator,
        exact_fallback=exact_fallback,
        elapsed_s=time.perf_counter() - t0,
    )


def grid_search_ev(
    evaluator: Evaluator,
    space: Mapping[str, Sequence[float]],
    *,
    exact_fallback: bool = True,
) -> TuningResult:
    """Exhaustive optimum inside the grid (exact oracle for the others)."""
    res = search_topk(evaluator, space, k=1, exact_fallback=exact_fallback)
    best = res.best()
    return TuningResult(best.assignment, best.cost,
                        evaluations=res.n_evaluated, topk=res)


def random_search_ev(
    evaluator: Evaluator,
    space: Mapping[str, Sequence[float]],
    *,
    samples: int = 4096,
    seed: int = 0,
    exact_fallback: bool = True,
) -> TuningResult:
    """Uniform sampling; evaluated in evaluator-sized blocks like the grid."""
    t0 = time.perf_counter()
    cand = sample_space(space, samples, seed)
    acc = TopKAccumulator(1)
    for start in range(0, samples, evaluator.chunk):
        stop = min(start + evaluator.chunk, samples)
        cols = {key: v[start:stop] for key, v in cand.items()}
        acc.update(start, cols, evaluator.chunk_topk(cols, 1))
    res = acc.finalize(evaluator, exact_fallback=exact_fallback,
                       elapsed_s=time.perf_counter() - t0)
    best = res.best()
    return TuningResult(best.assignment, best.cost,
                        evaluations=samples, topk=res)


def coordinate_descent_ev(
    evaluator: Evaluator,
    space: Mapping[str, Sequence[float]],
    *,
    max_rounds: int = 8,
    exact_fallback: bool = True,
) -> TuningResult:
    """Iterate per-parameter sweeps to a fixpoint (a handful of evaluator
    calls; reaches the grid optimum when the cost model is coordinate-wise
    quasi-convex, which holds on the benchmark spaces).

    A sweep whose rows are *all* invalid (closed-form model out of domain)
    is re-costed through ``evaluator.exact_cost`` when ``exact_fallback`` is
    set, matching :func:`search_topk`.  If no finite cost is ever found the
    function raises :class:`InvalidGridError` — it used to silently return
    a ``TuningResult`` with ``best_cost == inf`` and an arbitrary
    assignment.
    """
    keys = list(space.keys())
    assign = {k: float(space[k][len(space[k]) // 2]) for k in keys}
    evals = 0
    history: list[tuple[dict[str, float], float]] = []
    best_cost = np.inf
    best_exact = False

    for _ in range(max_rounds):
        changed = False
        for k in keys:
            cand = np.asarray(list(space[k]), dtype=np.float64)
            overrides: dict[str, np.ndarray] = {k: cand}
            for k2 in keys:
                if k2 != k:
                    overrides[k2] = np.full(len(cand), assign[k2])
            # the full chunked path on purpose: its single pre-compiled
            # executable beats per-sweep-shape retraces, and the padded
            # rows are far cheaper than a compile (measured in bench_tuner)
            res = evaluator.evaluate(overrides)
            evals += len(cand)
            costs = np.asarray(res.total_cost, dtype=np.float64)
            swept_exact = False
            if exact_fallback and not np.isfinite(costs).any():
                # whole sweep out of the closed-form domain: cost every
                # candidate via the exact simulator instead of argmin(inf)
                base = getattr(evaluator, "base_cfg", None)
                reasons = invalid_reason_counts(
                    res.outputs,
                    {**base, **overrides} if base is not None else None,
                )
                logger.info(
                    "valid==0 exact fallback: %s sweep (%d candidates) is "
                    "entirely out of the closed-form domain; failed "
                    "constraints: %s",
                    k, len(cand),
                    ", ".join(f"{n}={c}" for n, c in reasons.items())
                    or "not reported by this backend",
                )
                exact_costs = []
                for v in cand:
                    try:
                        exact_costs.append(
                            evaluator.exact_cost({**assign, k: float(v)}))
                    except ExactCostUnavailable as e:
                        logger.info("exact fallback skipped %s=%s: %s", k, v, e)
                        exact_costs.append(float("inf"))
                if None not in exact_costs:
                    costs = np.asarray(exact_costs, dtype=np.float64)
                    swept_exact = True
            i = int(np.argmin(costs))
            if costs[i] < best_cost - 1e-12:
                best_cost = float(costs[i])
                best_exact = swept_exact
                if assign[k] != float(cand[i]):
                    assign[k] = float(cand[i])
                    changed = True
            history.append((dict(assign), best_cost))
        if not changed:
            break

    if not np.isfinite(best_cost):
        raise InvalidGridError(
            "coordinate descent found no valid configuration (all sweeps "
            "invalid and no exact_cost escape hatch on this evaluator)"
        )
    return TuningResult(dict(assign), float(best_cost), evals, history,
                        exact=best_exact)


# --------------------------------------------------------------------------
# Hadoop-first wrappers (the seed repro.core.tuner signatures)
# --------------------------------------------------------------------------


def _hadoop_evaluator(p, s, c, evaluator, chunk):
    if evaluator is not None:
        return evaluator
    return cached_evaluator(p, s, c, chunk)


def grid_search(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    space: Mapping[str, Sequence[float]],
    *,
    evaluator: Evaluator | None = None,
    chunk: int | None = None,
    exact_fallback: bool = True,
) -> TuningResult:
    ev = _hadoop_evaluator(p, s, c, evaluator, chunk)
    return grid_search_ev(ev, space, exact_fallback=exact_fallback)


def random_search(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    space: Mapping[str, Sequence[float]],
    *,
    samples: int = 4096,
    seed: int = 0,
    evaluator: Evaluator | None = None,
    chunk: int | None = None,
    exact_fallback: bool = True,
) -> TuningResult:
    ev = _hadoop_evaluator(p, s, c, evaluator, chunk)
    return random_search_ev(ev, space, samples=samples, seed=seed,
                            exact_fallback=exact_fallback)


def coordinate_descent(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    space: Mapping[str, Sequence[float]],
    *,
    max_rounds: int = 8,
    evaluator: Evaluator | None = None,
    chunk: int | None = None,
    exact_fallback: bool = True,
) -> TuningResult:
    ev = _hadoop_evaluator(p, s, c, evaluator, chunk)
    return coordinate_descent_ev(ev, space, max_rounds=max_rounds,
                                 exact_fallback=exact_fallback)
