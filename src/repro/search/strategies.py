"""Search strategies over any :class:`repro.search.evaluator.Evaluator`.

The paper's "find the optimal configuration" use case, ported from the seed
``repro.core.tuner`` onto the chunked/sharded evaluator so the same three
strategies drive both the Hadoop job model (:class:`ChunkedEvaluator`) and
the TPU step model (:class:`repro.search.tpu.TpuEvaluator`):

* :func:`search_topk`            — streaming exhaustive top-k over a product
  space (the primitive everything else builds on).
* :func:`grid_search_ev`         — exhaustive optimum (k=1 wrapper).
* :func:`random_search_ev`       — uniform sampling of the space.
* :func:`coordinate_descent_ev`  — per-axis sweeps to a fixpoint.

``grid_search`` / ``random_search`` / ``coordinate_descent`` keep the seed's
Hadoop-first signatures (re-exported by ``repro.core.tuner``).
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from repro.core.hadoop.params import CostFactors, HadoopParams, ProfileStats
from repro.obs import current as _obs_current
from repro.spec.report import invalid_reason_counts

from .evaluator import (
    Evaluator,
    ExactCostUnavailable,
    InvalidGridError,
    NotDifferentiableError,
    apply_assignment,
    cached_evaluator,
)
from .grid import iter_blocks, sample_space
from .topk import TopKAccumulator, TopKResult

__all__ = [
    "TuningResult",
    "search_topk",
    "grid_search_ev",
    "random_search_ev",
    "coordinate_descent_ev",
    "build_relaxed_objective",
    "gradient_descent_ev",
    "grid_search",
    "random_search",
    "coordinate_descent",
]

logger = logging.getLogger("repro.search.strategies")


@dataclass
class TuningResult:
    best_assignment: dict[str, float]
    best_cost: float
    evaluations: int
    history: list[tuple[dict[str, float], float]] = field(default_factory=list)
    topk: TopKResult | None = None
    exact: bool = False     # best_cost came via the exact-simulator escape hatch

    def apply(self, p: HadoopParams) -> HadoopParams:
        """Materialize the winning assignment onto a HadoopParams object."""
        p2, _, _ = apply_assignment(p, ProfileStats(), CostFactors(),
                                    self.best_assignment)
        return p2


# --------------------------------------------------------------------------
# evaluator-generic strategies
# --------------------------------------------------------------------------


def search_topk(
    evaluator: Evaluator,
    space: Mapping[str, Sequence[float]],
    *,
    k: int = 1,
    exact_fallback: bool = True,
) -> TopKResult:
    """Stream the full Cartesian product through the evaluator in fixed-size
    blocks, reducing each block to its top-k on device and merging on host.

    Invalid (``valid == 0``) survivors of the final ranking are re-costed via
    the evaluator's exact path (simulator) rather than reported as ``inf``.
    """
    t0 = time.perf_counter()
    acc = TopKAccumulator(k)
    for start, cols in iter_blocks(space, evaluator.chunk):
        acc.update(start, cols, evaluator.chunk_topk(cols, k))
    return acc.finalize(
        evaluator,
        exact_fallback=exact_fallback,
        elapsed_s=time.perf_counter() - t0,
    )


def grid_search_ev(
    evaluator: Evaluator,
    space: Mapping[str, Sequence[float]],
    *,
    exact_fallback: bool = True,
) -> TuningResult:
    """Exhaustive optimum inside the grid (exact oracle for the others)."""
    res = search_topk(evaluator, space, k=1, exact_fallback=exact_fallback)
    best = res.best()
    return TuningResult(best.assignment, best.cost,
                        evaluations=res.n_evaluated, topk=res)


def random_search_ev(
    evaluator: Evaluator,
    space: Mapping[str, Sequence[float]],
    *,
    samples: int = 4096,
    seed: int = 0,
    exact_fallback: bool = True,
) -> TuningResult:
    """Uniform sampling; evaluated in evaluator-sized blocks like the grid."""
    t0 = time.perf_counter()
    cand = sample_space(space, samples, seed)
    acc = TopKAccumulator(1)
    for start in range(0, samples, evaluator.chunk):
        stop = min(start + evaluator.chunk, samples)
        cols = {key: v[start:stop] for key, v in cand.items()}
        acc.update(start, cols, evaluator.chunk_topk(cols, 1))
    res = acc.finalize(evaluator, exact_fallback=exact_fallback,
                       elapsed_s=time.perf_counter() - t0)
    best = res.best()
    return TuningResult(best.assignment, best.cost,
                        evaluations=samples, topk=res)


def coordinate_descent_ev(
    evaluator: Evaluator,
    space: Mapping[str, Sequence[float]],
    *,
    max_rounds: int = 8,
    exact_fallback: bool = True,
) -> TuningResult:
    """Iterate per-parameter sweeps to a fixpoint (a handful of evaluator
    calls; reaches the grid optimum when the cost model is coordinate-wise
    quasi-convex, which holds on the benchmark spaces).

    A sweep whose rows are *all* invalid (closed-form model out of domain)
    is re-costed through ``evaluator.exact_cost`` when ``exact_fallback`` is
    set, matching :func:`search_topk`.  If no finite cost is ever found the
    function raises :class:`InvalidGridError` — it used to silently return
    a ``TuningResult`` with ``best_cost == inf`` and an arbitrary
    assignment.
    """
    keys = list(space.keys())
    assign = {k: float(space[k][len(space[k]) // 2]) for k in keys}
    evals = 0
    history: list[tuple[dict[str, float], float]] = []
    best_cost = np.inf
    best_exact = False

    for _ in range(max_rounds):
        changed = False
        for k in keys:
            cand = np.asarray(list(space[k]), dtype=np.float64)
            overrides: dict[str, np.ndarray] = {k: cand}
            for k2 in keys:
                if k2 != k:
                    overrides[k2] = np.full(len(cand), assign[k2])
            # the full chunked path on purpose: its single pre-compiled
            # executable beats per-sweep-shape retraces, and the padded
            # rows are far cheaper than a compile (measured in bench_tuner)
            res = evaluator.evaluate(overrides)
            evals += len(cand)
            costs = np.asarray(res.total_cost, dtype=np.float64)
            swept_exact = False
            if exact_fallback and not np.isfinite(costs).any():
                # whole sweep out of the closed-form domain: cost every
                # candidate via the exact simulator instead of argmin(inf)
                base = getattr(evaluator, "base_cfg", None)
                reasons = invalid_reason_counts(
                    res.outputs,
                    {**base, **overrides} if base is not None else None,
                )
                logger.info(
                    "valid==0 exact fallback: %s sweep (%d candidates) is "
                    "entirely out of the closed-form domain; failed "
                    "constraints: %s",
                    k, len(cand),
                    ", ".join(f"{n}={c}" for n, c in reasons.items())
                    or "not reported by this backend",
                )
                exact_costs = []
                for v in cand:
                    try:
                        exact_costs.append(
                            evaluator.exact_cost({**assign, k: float(v)}))
                    except ExactCostUnavailable as e:
                        logger.info("exact fallback skipped %s=%s: %s", k, v, e)
                        exact_costs.append(float("inf"))
                if None not in exact_costs:
                    costs = np.asarray(exact_costs, dtype=np.float64)
                    swept_exact = True
            i = int(np.argmin(costs))
            if costs[i] < best_cost - 1e-12:
                best_cost = float(costs[i])
                best_exact = swept_exact
                if assign[k] != float(cand[i]):
                    assign[k] = float(cand[i])
                    changed = True
            history.append((dict(assign), best_cost))
        if not changed:
            break

    if not np.isfinite(best_cost):
        raise InvalidGridError(
            "coordinate descent found no valid configuration (all sweeps "
            "invalid and no exact_cost escape hatch on this evaluator)"
        )
    return TuningResult(dict(assign), float(best_cost), evals, history,
                        exact=best_exact)


def _search_axes(evaluator: Evaluator, space: Mapping[str, Sequence[float]]):
    """Per-key relaxation axes for a candidate space: the declared axis with
    its physical bounds tightened to the candidate range, so the sigmoid
    transform searches exactly the span the grid strategies see."""
    import dataclasses

    from repro.spec import Axis

    ps = evaluator.param_space
    axes = {}
    for k, cand in space.items():
        vals = np.asarray(list(cand), dtype=np.float64)
        ax = ps[k] if ps is not None and k in ps else Axis(name=k)
        if ax.kind == "bool":
            axes[k] = ax          # bools relax on (0, 1) regardless
            continue
        lo, hi = float(vals.min()), float(vals.max())
        if lo == hi:
            hi = lo + max(abs(lo) * 1e-9, 1e-9)   # degenerate 1-candidate axis
        axes[k] = dataclasses.replace(ax, lower=lo, upper=hi, lower_open=False)
    return axes


def build_relaxed_objective(evaluator: Evaluator,
                            space: Mapping[str, Sequence[float]]):
    """Build the relaxed scalar objective that gradient descent differentiates.

    Returns ``(raw_cost, axes, keys)``: ``raw_cost`` maps a dict of
    unconstrained per-key scalars to the evaluator's differentiable cost
    after per-axis :meth:`~repro.spec.Axis.project` transforms.  Raises
    :class:`NotDifferentiableError` for non-differentiable backends.
    Module-level (rather than a closure inside :func:`gradient_descent_ev`)
    so ``repro.analysis`` can trace exactly what the tuner descends.
    """
    objective = evaluator.grad_objective()
    keys = list(space.keys())
    axes = _search_axes(evaluator, space)

    def raw_cost(u_scalars):
        over = {k: axes[k].project(u_scalars[k]) for k in keys}
        cost, _ = objective(over)
        return cost

    return raw_cost, axes, keys


def gradient_descent_ev(
    evaluator: Evaluator,
    space: Mapping[str, Sequence[float]],
    *,
    steps: int = 80,
    restarts: int = 4,
    peak_lr: float = 0.5,
    seed: int = 0,
    checkpoints: int = 4,
    exact_fallback: bool = True,
) -> TuningResult:
    """First-order search over a continuous relaxation of the space.

    Each swept axis is relaxed to an unconstrained real via
    :meth:`repro.spec.Axis.relax`/:meth:`~repro.spec.Axis.project` — bounds
    through sigmoid transforms restricted to the candidate range, int/bool
    axes through straight-through rounding — and the evaluator's
    differentiable objective (:meth:`Evaluator.grad_objective`) is descended
    with the in-tree AdamW from ``restarts`` starting points at once
    (vmapped, so the whole search is a handful of compiled steps).

    The descent trajectory is then **rounded and validated**: projected
    assignments checkpointed along each restart are deduplicated, checked
    against the declared :class:`repro.spec.Predicate` constraints, and
    re-costed through ``evaluator.evaluate`` (masked total, with the
    ``exact_cost`` escape hatch) — the *reported* cost always comes from the
    evaluator, never from the relaxed objective.  ``evaluations`` counts
    those validation rows: the gradient steps differentiate the model
    directly and make no evaluator calls, which is how this strategy reaches
    the optimum in far fewer evaluator calls than coordinate descent
    (asserted in ``benchmarks/bench_tuner.py``).

    Backends without a differentiable objective (the cluster DES, the numpy
    TPU model) raise :class:`NotDifferentiableError`; this function falls
    back — loudly — to :func:`coordinate_descent_ev`.
    """
    try:
        raw_cost, axes, keys = build_relaxed_objective(evaluator, space)
    except NotDifferentiableError as e:
        logger.warning(
            "gradient_descent_ev: backend is not differentiable (%s); "
            "falling back to coordinate_descent_ev", e)
        return coordinate_descent_ev(
            evaluator, space, exact_fallback=exact_fallback)

    import jax
    import jax.numpy as jnp

    from repro.optim import AdamWConfig, adamw_init, adamw_update

    rng = np.random.default_rng(seed)

    # Starting points: restart 0 at the per-axis midpoint candidate (the
    # coordinate-descent start), the rest uniform over the candidate range.
    u0 = {}
    for k in keys:
        vals = np.asarray(list(space[k]), dtype=np.float64)
        lo, hi = float(vals.min()), float(vals.max())
        starts = [float(vals[len(vals) // 2])]
        starts += list(rng.uniform(lo, hi, size=max(0, restarts - 1)))
        u0[k] = jnp.asarray([float(axes[k].relax(v)) for v in starts[:restarts]])

    opt_cfg = AdamWConfig(
        peak_lr=peak_lr,
        warmup_steps=max(1, steps // 10),
        total_steps=steps,
        weight_decay=0.0,
        # effectively unclipped: Adam's sqrt(v) normalization already bounds
        # the per-axis step, and clipping across restarts would couple them
        grad_clip_norm=1e6,
    )
    state = adamw_init(u0)

    @jax.jit
    def step(u, state):
        # vals ride along for observability: the per-restart relaxed cost
        # at the pre-update point (value_and_grad computes them anyway)
        vals, grads = jax.vmap(jax.value_and_grad(raw_cost))(u)
        grads = {k: jnp.nan_to_num(g, nan=0.0, posinf=0.0, neginf=0.0)
                 for k, g in grads.items()}
        new_u, new_state, _ = adamw_update(grads, state, u, opt_cfg)
        return vals, new_u, new_state

    def snapshot(u) -> list[dict[str, float]]:
        return [
            {k: float(axes[k].project(u[k][r])) for k in keys}
            for r in range(restarts)
        ]

    ob = _obs_current()
    candidates: list[dict[str, float]] = snapshot(u0)
    u = u0
    every = max(1, steps // max(1, checkpoints))
    for i in range(steps):
        vals, u, state = step(u, state)
        if (i + 1) % every == 0 or i == steps - 1:
            candidates += snapshot(u)
            if ob.enabled:
                v = np.asarray(vals, dtype=np.float64)
                v = v[np.isfinite(v)]
                if v.size:
                    ob.tracer.counter("tuner", best_relaxed_cost=float(v.min()))
    if ob.enabled:
        ob.registry.counter("tuner.gradient_steps").inc(steps)

    # ---- round-and-validate: dedupe, predicate-check, evaluator re-cost ----
    seen: set[tuple] = set()
    rows: list[dict[str, float]] = []
    for cand in candidates:
        key = tuple(round(cand[k], 12) for k in keys)
        if key not in seen:
            seen.add(key)
            rows.append(cand)

    ps = evaluator.param_space
    if ps is not None and ps.predicates:
        cols = {k: np.asarray([r[k] for r in rows]) for k in keys}
        ok, reasons = ps.validity_mask(cols)
        if not ok.all():
            dropped = int((~ok).sum())
            failed = [n for n, m in reasons.items() if not m.all()]
            logger.info(
                "gradient_descent_ev: dropped %d/%d projected candidates "
                "failing declared predicates (%s)",
                dropped, len(rows), ", ".join(failed))
            rows = [r for r, good in zip(rows, ok) if good]
    if not rows:
        logger.warning(
            "gradient_descent_ev: every projected candidate failed the "
            "declared predicates; falling back to coordinate_descent_ev")
        return coordinate_descent_ev(
            evaluator, space, exact_fallback=exact_fallback)

    overrides = {k: np.asarray([r[k] for r in rows]) for k in keys}
    res = evaluator.evaluate(overrides)
    evals = len(rows)
    if ob.enabled:
        ob.registry.counter("tuner.evaluator_calls").inc()
        ob.registry.counter("tuner.validated_rows").inc(evals)
    costs = np.asarray(res.total_cost, dtype=np.float64)

    best_exact = False
    if exact_fallback and not np.isfinite(costs).any():
        exact_costs = []
        for r in rows:
            try:
                exact_costs.append(evaluator.exact_cost(r))
            except ExactCostUnavailable as e:
                logger.info("exact fallback skipped %s: %s", r, e)
                exact_costs.append(float("inf"))
        if None not in exact_costs:
            costs = np.asarray(exact_costs, dtype=np.float64)
            best_exact = True

    if not np.isfinite(costs).any():
        logger.warning(
            "gradient_descent_ev: no validated candidate has a finite cost; "
            "falling back to coordinate_descent_ev")
        return coordinate_descent_ev(
            evaluator, space, exact_fallback=exact_fallback)

    order = np.argsort(costs, kind="stable")
    history = [(dict(rows[i]), float(costs[i])) for i in order[::-1]]
    i = int(order[0])
    return TuningResult(dict(rows[i]), float(costs[i]), evals, history,
                        exact=best_exact)


# --------------------------------------------------------------------------
# Hadoop-first wrappers (the seed repro.core.tuner signatures)
# --------------------------------------------------------------------------


def _hadoop_evaluator(p, s, c, evaluator, chunk):
    if evaluator is not None:
        return evaluator
    return cached_evaluator(p, s, c, chunk)


def grid_search(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    space: Mapping[str, Sequence[float]],
    *,
    evaluator: Evaluator | None = None,
    chunk: int | None = None,
    exact_fallback: bool = True,
) -> TuningResult:
    ev = _hadoop_evaluator(p, s, c, evaluator, chunk)
    return grid_search_ev(ev, space, exact_fallback=exact_fallback)


def random_search(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    space: Mapping[str, Sequence[float]],
    *,
    samples: int = 4096,
    seed: int = 0,
    evaluator: Evaluator | None = None,
    chunk: int | None = None,
    exact_fallback: bool = True,
) -> TuningResult:
    ev = _hadoop_evaluator(p, s, c, evaluator, chunk)
    return random_search_ev(ev, space, samples=samples, seed=seed,
                            exact_fallback=exact_fallback)


def coordinate_descent(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    space: Mapping[str, Sequence[float]],
    *,
    max_rounds: int = 8,
    evaluator: Evaluator | None = None,
    chunk: int | None = None,
    exact_fallback: bool = True,
) -> TuningResult:
    ev = _hadoop_evaluator(p, s, c, evaluator, chunk)
    return coordinate_descent_ev(ev, space, max_rounds=max_rounds,
                                 exact_fallback=exact_fallback)
