"""Async what-if query service: many tenants, one compiled evaluator.

The paper's headline use case — "what happens to the job if I change X?" —
arrives in production as a stream of *small heterogeneous* queries: a
single-config probe here, a per-axis sweep there, the occasional full grid.
Evaluating each one through its own :meth:`ChunkedEvaluator.evaluate` call
wastes almost the whole chunk: a 3-row sweep still pays for ``chunk`` padded
rows and a dispatch.

:class:`WhatIfService` applies the continuous-batching design of
:mod:`repro.runtime.serve_loop` to model evaluation.  Queries enter a shared
:class:`~repro.runtime.batching.AdmissionQueue`; a worker thread packs the
waiting rows — FIFO, across query boundaries — into the evaluator's
fixed-size chunk ("row slots" instead of KV-cache slots), runs the
pre-compiled executable for that key-set, and scatters results back to each
query's future.  A query larger than a chunk streams across several chunks;
a chunk usually carries rows from several queries.

Correctness contract (tested in ``tests/test_service.py``):

* **Equivalence** — a query's resolved outputs are bit-for-bit identical to
  a sequential ``evaluator.evaluate(rows)`` call on the query's rows (its
  overrides with scalars broadcast to per-row columns — the form
  ``evaluate`` itself requires for a 1-row probe).  This is structural,
  not approximate: a chunk only coalesces queries that sweep the *same
  key-set*, so it runs the exact executable the sequential call runs, and
  rows are bitwise-independent of their chunk neighbours (the evaluator's
  padding invariant).  Batching a key the sequential call left static
  would compile a different executable and can differ in the last float
  bit — the service never does that silently; the ``keys=...`` mode makes
  the expansion explicit.
* **No silent ``inf``** — rows whose closed-form model is out of domain
  (``valid == 0``) are re-costed through the evaluator's exact simulator
  path when the query asks for it (``exact_fallback=True``), and
  :meth:`QueryResult.best` raises :class:`InvalidGridError` rather than
  returning an unusable row otherwise.
* **Accounting** — per-query end-to-end latency (submit -> future resolved),
  queue depth at admission, and chunk-sharing counters; service-level
  p50/p99 via :class:`~repro.runtime.batching.LatencyStats`.
"""

from __future__ import annotations

import itertools
import logging
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

import numpy as np

from repro.obs import current as _obs_current
from repro.runtime.batching import AdmissionQueue, LatencyStats
from repro.spec import CostReport, PhaseBreakdown
from repro.spec.report import invalid_reasons

from .evaluator import (
    Evaluator,
    ExactCostUnavailable,
    InvalidGridError,
    SearchResult,
    masked_total,
)
from .grid import space_block, space_size

__all__ = ["QueryStats", "QueryResult", "PhaseQueryResult", "WhatIfService"]

logger = logging.getLogger("repro.search.service")


@dataclass
class QueryStats:
    """Per-query service accounting, attached to every :class:`QueryResult`."""

    latency_s: float = 0.0        # submit -> future resolved (end-to-end)
    queue_depth: int = 0          # queries already waiting at submit time
    n_rows: int = 0               # rows this query expanded to
    n_chunks: int = 0             # evaluator chunks its rows rode in
    n_shared_chunks: int = 0      # of those, chunks shared with other queries
    n_exact: int = 0              # rows re-costed via the exact simulator


@dataclass
class QueryResult(SearchResult):
    """A resolved query: :class:`SearchResult` (so ``best()`` keeps the
    raise-on-all-invalid semantics) plus the escape-hatch row mask and the
    service accounting.  ``total_cost`` holds exact-simulator seconds where
    ``exact`` is set, model seconds elsewhere, ``inf`` only for invalid rows
    the query did not ask to re-cost."""

    exact: np.ndarray = field(default_factory=lambda: np.zeros(0, dtype=bool))
    stats: QueryStats = field(default_factory=QueryStats)


@dataclass
class PhaseQueryResult:
    """A resolved *per-phase* what-if query (the typed query type).

    ``objective`` is the chosen phase's job-level cost per row
    (:class:`repro.spec.PhaseBreakdown` field, seconds); ``feasible`` marks
    rows that are model-valid AND satisfy the total-cost constraint.
    ``report`` is the full typed :class:`repro.spec.CostReport`, so callers
    can inspect every other phase (and the disaggregated validity flags) of
    the rows they asked about.
    """

    overrides: dict[str, np.ndarray]
    report: CostReport
    phase: str
    objective: np.ndarray
    feasible: np.ndarray
    total_max: float | None = None
    stats: QueryStats = field(default_factory=QueryStats)

    def best(self) -> tuple[int, float, dict[str, float]]:
        """Index, phase cost and assignment of the best feasible row."""
        obj = np.where(self.feasible, np.asarray(self.objective), np.inf)
        if obj.size == 0 or not np.isfinite(obj).any():
            constraint = (f" under total_cost <= {self.total_max}"
                          if self.total_max is not None else "")
            raise InvalidGridError(
                f"no feasible configuration for phase {self.phase!r}"
                f"{constraint}; invalid-constraint reasons: "
                + ("; ".join(self.report.invalid_reasons()) or "none")
            )
        i = int(np.argmin(obj))
        return i, float(obj[i]), {
            k: float(v[i]) for k, v in self.overrides.items()
        }


class _Query:
    """Internal pending-query record (rows + scatter-back accumulators)."""

    __slots__ = (
        "qid", "cols", "sig", "n", "taken", "done_rows", "outputs", "future",
        "exact_fallback", "t_submit", "stats",
    )

    def __init__(self, qid: int, cols: dict[str, np.ndarray], n: int,
                 exact_fallback: bool):
        self.qid = qid
        self.cols = cols              # the query's row columns, (n,) each
        self.sig = tuple(sorted(cols))   # key-set = executable identity
        self.n = n
        self.taken = 0                # rows already packed into chunks
        self.done_rows = 0
        self.outputs: dict[str, np.ndarray] | None = None
        self.future: Future = Future()
        self.exact_fallback = exact_fallback
        self.t_submit = time.perf_counter()
        self.stats = QueryStats(n_rows=n)


class WhatIfService:
    """Coalesce concurrent what-if queries into shared evaluator chunks.

    Parameters
    ----------
    evaluator : the shared (usually :class:`ChunkedEvaluator`) backend; its
        ``chunk`` is the row-slot count of one admission tick, and one
        compiled executable per swept key-set serves every tenant (exactly
        the executables sequential callers would compile).
    keys : optional fixed universe of sweepable config keys.  When given,
        every query is expanded to sweep this whole key-set at admission
        (absent keys ride along at their base-config values), so ALL
        tenants share a single key-set — and a single compiled executable
        for the service's lifetime.  Queries may then only use keys from
        the universe.  When ``None``, queries keep their own key-sets and
        only same-key-set queries coalesce into a chunk.
    window_s : admission window — after waking on work, the worker waits up
        to this long for more rows while the chunk is not yet full (the
        continuous-batching knob; 0 disables).  Bulk :meth:`map` submissions
        enqueue under one lock and do not need a window to coalesce.
    """

    def __init__(self, evaluator: Evaluator, *,
                 keys: Sequence[str] | None = None,
                 window_s: float = 0.0):
        self.evaluator = evaluator
        base = getattr(evaluator, "base_cfg", None)
        if base is None:
            raise TypeError(
                "WhatIfService needs an evaluator exposing base_cfg "
                "(a ChunkedEvaluator-style backend)"
            )
        self._base = {k: np.asarray(v) for k, v in base.items()}
        self._universe: list[str] | None = None
        if keys is not None:
            for k in keys:
                self._check_key(k)
            self._universe = list(dict.fromkeys(keys))
        self.window_s = float(window_s)
        self._queue: AdmissionQueue[_Query] = AdmissionQueue()
        self._qid = itertools.count()
        self._lock = threading.Lock()
        self.latency = LatencyStats()
        self.stats = {
            "queries": 0,
            "rows": 0,
            "chunks": 0,           # evaluator calls issued
            "shared_chunks": 0,    # chunks carrying >1 query
            "rows_padded": 0,      # slack rows in partially-filled chunks
            "exact_rows": 0,       # escape-hatch simulator re-costs
        }
        self._worker = threading.Thread(
            target=self._run, name="whatif-service", daemon=True
        )
        self._worker.start()

    # ------------------------------------------------------------------
    # submission API
    # ------------------------------------------------------------------

    def _check_key(self, k: str) -> None:
        if k not in self._base:
            raise KeyError(f"unknown config key: {k!r}")

    def _normalize(self, overrides: Mapping[str, Any]) -> tuple[dict, int]:
        """Validate an override mapping and expand it to (n,) row columns.

        Scalars broadcast; 1-D values must agree on a common length.  An
        all-scalar mapping is a single-config probe (n=1).  In fixed-
        universe mode, keys the query did not override are filled with
        their base-config values so every tenant sweeps the same key-set.
        """
        if not overrides:
            raise ValueError("query has no overrides")
        n = None
        arrs: dict[str, np.ndarray] = {}
        for k, v in overrides.items():
            self._check_key(k)
            if self._universe is not None and k not in self._universe:
                raise KeyError(
                    f"key {k!r} is outside this service's fixed key "
                    f"universe {self._universe}"
                )
            a = np.asarray(v, dtype=self._base[k].dtype)
            if a.ndim > 1:
                raise ValueError(f"override {k!r} must be scalar or 1-D")
            if a.ndim == 1:
                if a.size == 0:
                    raise ValueError(f"override {k!r} is empty (0-length query)")
                if n is None:
                    n = a.size
                elif a.size != n:
                    raise ValueError("all batched overrides must share a length")
            arrs[k] = a
        n = 1 if n is None else n
        cols = {
            k: (a if a.ndim == 1 else np.full(n, a, dtype=a.dtype))
            for k, a in arrs.items()
        }
        if self._universe is not None:
            for k in self._universe:
                if k not in cols:
                    fill = self._base[k]
                    cols[k] = np.full(n, fill, dtype=fill.dtype)
        return cols, n

    def submit(self, overrides: Mapping[str, Any], *,
               exact_fallback: bool = False) -> Future:
        """Admit one query; returns a future resolving to :class:`QueryResult`.

        ``overrides`` maps config keys to a scalar (applied to every row) or
        a 1-D array of per-row values — the same contract as
        ``ChunkedEvaluator.evaluate``, whose sequential result this query's
        resolution is bit-for-bit equal to.
        """
        cols, n = self._normalize(overrides)
        q = self._make_query(cols, n, exact_fallback)
        # depth is recorded BEFORE publishing: once put() returns, a fast
        # worker may already have resolved the future and handed q.stats out
        q.stats.queue_depth = len(self._queue)
        depth = self._queue.put(q)
        ob = _obs_current()
        if ob.enabled:
            ob.tracer.counter("service queue", depth=depth)
        return q.future

    def probe(self, assignment: Mapping[str, float], *,
              exact_fallback: bool = True) -> Future:
        """Single-config what-if probe (1 row; escape hatch on by default —
        a probe of an out-of-domain config should cost it, not return inf)."""
        return self.submit(assignment, exact_fallback=exact_fallback)

    def sweep(self, key: str, values: Sequence[float], *,
              base: Mapping[str, float] | None = None,
              exact_fallback: bool = False) -> Future:
        """Per-axis sweep: ``key`` takes each of ``values``; ``base`` pins
        other keys for every row."""
        ov: dict[str, Any] = dict(base or {})
        ov[key] = np.asarray(list(values), dtype=np.float64)
        return self.submit(ov, exact_fallback=exact_fallback)

    def grid(self, space: Mapping[str, Sequence[float]], *,
             base: Mapping[str, float] | None = None,
             exact_fallback: bool = False) -> Future:
        """Full Cartesian grid over ``space`` (streamed through as many
        chunks as it needs; rides shared chunks at its edges)."""
        cols = space_block(space, 0, space_size(space))
        ov: dict[str, Any] = dict(base or {})
        ov.update(cols)
        return self.submit(ov, exact_fallback=exact_fallback)

    def phase_query(self, overrides: Mapping[str, Any], *,
                    phase: str, total_max: float | None = None) -> Future:
        """Typed per-phase what-if query: minimize one phase's cost, with an
        optional job-total budget.

        "Which of these configs minimizes ``shuffle`` time subject to
        ``j_totalCost <= total_max``?"  ``phase`` is a
        :class:`repro.spec.PhaseBreakdown` field; rows are evaluated through
        the exact same coalesced chunks as :meth:`submit` (identical
        numbers), then lifted into a :class:`repro.spec.CostReport` — the
        future resolves to :class:`PhaseQueryResult`.  Requires a backend
        with phase reports (the Hadoop job model).
        """
        if phase not in PhaseBreakdown.names():
            raise KeyError(
                f"unknown phase: {phase!r} (phases: {list(PhaseBreakdown.names())})"
            )
        inner = self.submit(overrides)
        out: Future = Future()

        def _lift(f: Future) -> None:
            try:
                out.set_result(self._phase_result(f.result(), phase, total_max))
            except BaseException as e:
                out.set_exception(e)

        inner.add_done_callback(_lift)
        return out

    def _phase_result(self, qr: QueryResult, phase: str,
                      total_max: float | None) -> PhaseQueryResult:
        if "m_ioReadCost" not in qr.outputs:
            raise TypeError(
                "phase queries need per-phase model outputs (the Hadoop job "
                f"model); this service's backend emits {sorted(qr.outputs)[:4]}..."
            )
        cfg = {**self._base, **qr.overrides}
        report = CostReport.from_outputs(qr.outputs, cfg)
        feasible = np.asarray(qr.outputs["valid"]) > 0
        if total_max is not None:
            feasible = feasible & (np.asarray(report.total_cost) <= total_max)
        return PhaseQueryResult(
            overrides=dict(qr.overrides),
            report=report,
            phase=phase,
            objective=np.asarray(report.phases[phase]),
            feasible=feasible,
            total_max=total_max,
            stats=qr.stats,
        )

    def map(self, queries: Sequence[Mapping[str, Any]], *,
            exact_fallback: bool = False) -> list[QueryResult]:
        """Submit many queries under one admission lock and wait for all —
        the multi-query path ``repro.core.whatif.evaluate_queries`` uses.
        One wake-up sees every row, so coalescing is deterministic."""
        qs = []
        for ov in queries:
            cols, n = self._normalize(ov)
            qs.append(self._make_query(cols, n, exact_fallback))
        depth = len(self._queue)
        for i, q in enumerate(qs):
            q.stats.queue_depth = depth + i
        self._queue.put_many(qs)
        ob = _obs_current()
        if ob.enabled:
            ob.tracer.counter("service queue", depth=depth + len(qs))
        return [q.future.result() for q in qs]

    def _make_query(self, cols, n, exact_fallback) -> _Query:
        q = _Query(next(self._qid), cols, n, exact_fallback)
        with self._lock:
            self.stats["queries"] += 1
            self.stats["rows"] += n
        ob = _obs_current()
        if ob.enabled:
            ob.registry.counter("service.queries").inc()
            ob.registry.counter("service.rows").inc(n)
            # async span: begins here on the submitting thread, ends in
            # _resolve on the worker — the query's submit->resolve life
            ob.tracer.async_begin("query", q.qid, rows=n,
                                  keys=",".join(q.sig))
        return q

    # ------------------------------------------------------------------
    # worker: pack -> evaluate -> scatter
    # ------------------------------------------------------------------

    def _run(self) -> None:
        chunk = self.evaluator.chunk
        while True:
            if not self._queue.wait():
                return                      # closed and drained
            if self.window_s > 0:
                deadline = time.perf_counter() + self.window_s
                while (time.perf_counter() < deadline
                       and self._pending_rows() < chunk):
                    time.sleep(min(self.window_s / 10, 1e-3))
            segments = self._pack(chunk)
            if segments:
                try:
                    self._evaluate_segments(segments)
                except BaseException as e:     # resolve, don't kill the loop
                    for q, _, _, _ in segments:
                        # drop a partially-packed query's remaining rows
                        # BEFORE failing its future — they would be wasted
                        # chunks, and a caller unblocked by the exception
                        # must not observe the dead query still queued
                        if q.taken < q.n:
                            self._queue.remove(q)
                        if not q.future.done():
                            q.future.set_exception(e)

    def _pending_rows(self) -> int:
        """Rows the NEXT chunk could actually pack: only queries sharing the
        head query's key-set coalesce, so other signatures don't count."""
        items = self._queue.items()
        if not items:
            return 0
        sig = items[0].sig
        return sum(q.n - q.taken for q in items if q.sig == sig)

    def _pack(self, chunk: int) -> list[tuple[_Query, int, int, int]]:
        """Fill up to ``chunk`` row slots FIFO across query boundaries,
        coalescing only queries that sweep the head query's key-set (so the
        chunk runs exactly the executable their sequential calls would).
        Returns ``(query, query_row_start, n_rows, chunk_offset)`` segments;
        a query leaves the queue once all its rows are packed."""
        segments: list[tuple[_Query, int, int, int]] = []
        offset = 0
        sig = None
        for q in self._queue.items():       # FIFO snapshot; worker-only pops
            if offset >= chunk:
                break
            if sig is None:
                sig = q.sig
            elif q.sig != sig:
                continue                    # different executable: next chunk
            take = min(chunk - offset, q.n - q.taken)
            segments.append((q, q.taken, take, offset))
            q.taken += take
            offset += take
            if q.taken == q.n:
                self._queue.remove(q)
        return segments

    def _evaluate_segments(self, segments) -> None:
        n_rows = sum(take for _, _, take, _ in segments)
        cols: dict[str, np.ndarray] = {}
        for k in segments[0][0].sig:        # shared key-set by construction
            col = np.empty(n_rows, dtype=segments[0][0].cols[k].dtype)
            for q, q_start, take, offset in segments:
                col[offset:offset + take] = q.cols[k][q_start:q_start + take]
            cols[k] = col

        ob = _obs_current()
        with ob.tracer.span("service.chunk", rows=n_rows,
                            queries=len(segments)):
            out = self.evaluator.evaluate(cols).outputs
        with self._lock:
            self.stats["chunks"] += 1
            if len(segments) > 1:
                self.stats["shared_chunks"] += 1
            self.stats["rows_padded"] += self.evaluator.chunk - n_rows
        if ob.enabled:
            reg = ob.registry
            reg.counter("service.chunks").inc()
            if len(segments) > 1:
                reg.counter("service.shared_chunks").inc()
            reg.counter("service.rows_padded").inc(
                self.evaluator.chunk - n_rows)
            ob.tracer.counter("chunk sharing",
                              queries_per_chunk=len(segments))
            ob.tracer.counter("service queue", depth=len(self._queue))

        shared = len(segments) > 1
        for q, q_start, take, offset in segments:
            if q.outputs is None:
                q.outputs = {k: np.empty(q.n, dtype=v.dtype)
                             for k, v in out.items()}
            for k, v in out.items():
                q.outputs[k][q_start:q_start + take] = v[offset:offset + take]
            q.done_rows += take
            q.stats.n_chunks += 1
            q.stats.n_shared_chunks += int(shared)
            if q.done_rows == q.n:
                self._resolve(q)

    def _resolve(self, q: _Query) -> None:
        outputs = q.outputs
        valid = outputs["valid"] > 0
        total = masked_total(outputs, self.evaluator.cost_key)
        exact = np.zeros(q.n, dtype=bool)
        if q.exact_fallback and not valid.all():
            cfg = {**self._base, **q.cols}
            for i in np.flatnonzero(~valid):
                try:
                    cost = self.evaluator.exact_cost(
                        {k: float(v[i]) for k, v in q.cols.items()}
                    )
                except ExactCostUnavailable as e:
                    logger.info("exact fallback skipped query %d row %d: %s",
                                q.qid, i, e)
                    continue            # row stays inf, explicitly logged
                if cost is None:
                    break               # backend has no exact path
                logger.info(
                    "valid==0 exact fallback: query %d row %d re-costed via "
                    "the exact simulator (%.6gs); failed constraints: %s",
                    q.qid, i, cost,
                    "; ".join(invalid_reasons(outputs, i, cfg)) or "unknown",
                )
                total[i] = cost
                exact[i] = True
            with self._lock:
                self.stats["exact_rows"] += int(exact.sum())
            q.stats.n_exact = int(exact.sum())
        q.stats.latency_s = time.perf_counter() - q.t_submit
        self.latency.record(q.stats.latency_s)
        ob = _obs_current()
        if ob.enabled:
            ob.registry.histogram("service.latency_s").record(
                q.stats.latency_s)
            if q.stats.n_exact:
                ob.registry.counter("service.exact_rows").inc(q.stats.n_exact)
            ob.tracer.async_end("query", q.qid,
                                chunks=q.stats.n_chunks,
                                shared=q.stats.n_shared_chunks)
        q.future.set_result(QueryResult(
            overrides=dict(q.cols),
            outputs=outputs,
            total_cost=total,
            exact=exact,
            stats=q.stats,
        ))

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def close(self, wait: bool = True) -> None:
        """Stop admitting; the worker drains already-queued queries, then
        exits.  Idempotent."""
        self._queue.close()
        if wait and self._worker.is_alive():
            self._worker.join()

    def __enter__(self) -> "WhatIfService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def summary(self) -> dict:
        """Service-level counters + latency percentiles (for benchmarks)."""
        with self._lock:
            s = dict(self.stats)
        s["peak_queue_depth"] = self._queue.peak_depth
        s.update({f"latency_{k}": v for k, v in self.latency.summary().items()})
        return s
