"""Streaming Cartesian config grids.

A search space is a ``Mapping[str, Sequence[float]]`` (config key ->
candidate values).  The full product is never materialized: blocks of flat
indices are unraveled into per-key value columns on demand, so a 10^6+ grid
streams through the chunked evaluator in bounded memory.
"""

from __future__ import annotations

from typing import Iterator, Mapping, Sequence

import numpy as np

__all__ = ["space_size", "space_block", "iter_blocks", "sample_space", "assignment_at"]


def _axes(space: Mapping[str, Sequence[float]]) -> tuple[list[str], list[np.ndarray]]:
    keys = list(space.keys())
    vals = [np.asarray(list(space[k]), dtype=np.float64) for k in keys]
    for k, v in zip(keys, vals):
        if v.ndim != 1 or v.size == 0:
            raise ValueError(f"space axis {k!r} must be a non-empty 1-D sequence")
    return keys, vals


def space_size(space: Mapping[str, Sequence[float]]) -> int:
    """Number of configs in the Cartesian product."""
    _, vals = _axes(space)
    n = 1
    for v in vals:
        n *= v.size
    return n


def space_block(
    space: Mapping[str, Sequence[float]], start: int, stop: int
) -> dict[str, np.ndarray]:
    """Columns for flat product indices ``[start, stop)`` (C order: last key
    varies fastest — the order ``itertools.product`` would produce)."""
    keys, vals = _axes(space)
    shape = tuple(v.size for v in vals)
    flat = np.arange(start, stop, dtype=np.int64)
    idx = np.unravel_index(flat, shape)
    return {k: v[i] for k, v, i in zip(keys, vals, idx)}


def assignment_at(space: Mapping[str, Sequence[float]], i: int) -> dict[str, float]:
    """The single product assignment at flat index ``i``."""
    block = space_block(space, i, i + 1)
    return {k: float(v[0]) for k, v in block.items()}


def iter_blocks(
    space: Mapping[str, Sequence[float]], block: int
) -> Iterator[tuple[int, dict[str, np.ndarray]]]:
    """Yield ``(start_index, columns)`` blocks of at most ``block`` configs."""
    n = space_size(space)
    for start in range(0, n, block):
        yield start, space_block(space, start, min(start + block, n))


def sample_space(
    space: Mapping[str, Sequence[float]], n: int, seed: int = 0
) -> dict[str, np.ndarray]:
    """Uniform i.i.d. samples from the product space (with replacement)."""
    keys, vals = _axes(space)
    rng = np.random.default_rng(seed)
    return {k: v[rng.integers(0, v.size, size=n)] for k, v in zip(keys, vals)}
