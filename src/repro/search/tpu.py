"""The TPU-side tuner on the shared search subsystem.

The paper's methodology transplanted to TPU step costs
(:mod:`repro.core.tpu_model`): rank (dp, tp, n_micro, remat) execution
configurations for a model/shape *without running them*.  The step model is
pure Python over static shapes (a few hundred candidates, microseconds
each), so :class:`TpuEvaluator` is a numpy backend behind the exact same
:class:`~repro.search.evaluator.Evaluator` interface the chunked Hadoop
evaluator implements — every strategy in :mod:`repro.search.strategies`
(and ``examples/tpu_tuning.py``) runs unchanged against either cost model.

Validity here is *shardability* (the GSPMD analogue of the paper's merge
domain): a candidate is invalid when ``dp * tp`` misses the chip budget or
the global batch does not factor over (dp, n_micro).  There is no exact
escape hatch — an unshardable mesh has no cost, exact or otherwise.
"""

from __future__ import annotations

from typing import Any, Mapping, Sequence

import numpy as np

from repro.core.tpu_model import TpuCostFactors, TpuParams, step_model
from repro.models.config import ModelConfig
from repro.spec import Axis, ParamSpace, Predicate

from .evaluator import Evaluator, SearchResult, masked_total
from .strategies import search_topk
from .topk import TopKResult

__all__ = ["TpuEvaluator", "tune_tpu", "mesh_space", "TPU_AXIS_NAMES"]

#: the sweepable execution-config axes (frozen in repro/spec/manifest.json)
TPU_AXIS_NAMES = ("dp", "tp", "n_micro", "remat", "ep")
_SWEEPABLE = TPU_AXIS_NAMES


class TpuEvaluator(Evaluator):
    """Batched evaluation of :func:`repro.core.tpu_model.step_model`.

    ``overrides`` columns may sweep any of ``dp/tp/n_micro/remat/ep``;
    unswept fields come from ``base``.  ``ep`` defaults to ``tp`` whenever
    the expert count divides it (the layout ``examples/tpu_tuning.py``
    hillclimbed to).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        shape,                      # repro.configs.shapes.Shape
        *,
        costs: TpuCostFactors | None = None,
        base: TpuParams | None = None,
        n_chips: int | None = None,
        objective: str = "overlap_s",
    ):
        self.cfg = cfg
        self.shape = shape
        self.costs = costs or TpuCostFactors()
        self.base = base or TpuParams()
        self.n_chips = n_chips
        self.objective = objective
        self._space = self._build_space()

    @property
    def cost_key(self) -> str:
        return self.objective

    @property
    def param_space(self) -> ParamSpace:
        """Declared mesh axes + shardability predicates — the GSPMD analogue
        of the paper's merge-domain validity, made inspectable."""
        return self._space

    def grad_objective(self):
        from .evaluator import NotDifferentiableError

        raise NotDifferentiableError(
            "the TPU step model is a pure-numpy table model over integer "
            "mesh layouts (dp/tp/n_micro are divisor-constrained ints) — "
            "there is no differentiable relaxation; gradient strategies "
            "fall back to coordinate descent here"
        )

    def _build_space(self) -> ParamSpace:
        gb = self.shape.global_batch
        preds = []
        if self.n_chips is not None:
            n = self.n_chips
            preds.append(Predicate(
                "chipBudget",
                lambda c, n=n: c["dp"] * c["tp"] == n,
                doc=f"dp * tp must equal the chip budget ({n})",
            ))
        preds.append(Predicate(
            "batchDivides",
            lambda c: gb % np.maximum(c["dp"], 1) == 0,
            doc=f"dp must divide the global batch ({gb})",
        ))
        preds.append(Predicate(
            "microDivides",
            lambda c: (c["n_micro"] == 1)
            | ((gb // np.maximum(c["dp"], 1)) % np.maximum(c["n_micro"], 1) == 0),
            doc="n_micro must divide the per-replica batch",
        ))
        axes = [
            Axis("dp", kind="int", lower=1, group="mesh", doc="data-parallel ways"),
            Axis("tp", kind="int", lower=1, group="mesh",
                 doc="tensor/model-parallel ways"),
            Axis("n_micro", kind="int", lower=1, group="mesh",
                 doc="gradient-accumulation microbatches"),
            Axis("remat", kind="bool", group="mesh",
                 doc="recompute activations in backward"),
            Axis("ep", kind="int", lower=1, group="mesh",
                 doc="expert-parallel ways (<= tp)"),
        ]
        return ParamSpace(axes, preds)

    def _row_params(self, row: Mapping[str, float]) -> TpuParams:
        kw: dict[str, Any] = {
            k: self._space.coerce(k, row[k]) for k in _SWEEPABLE if k in row
        }
        p = TpuParams(**{**_as_kwargs(self.base), **kw})
        if "ep" not in kw:
            ep = p.tp if self.cfg.n_experts and self.cfg.n_experts % p.tp == 0 else 1
            p = TpuParams(**{**_as_kwargs(p), "ep": ep})
        return p

    def _row_valid(self, p: TpuParams) -> bool:
        ok, _ = self._space.validity_mask({
            "dp": np.asarray(p.dp), "tp": np.asarray(p.tp),
            "n_micro": np.asarray(p.n_micro), "remat": np.asarray(p.remat),
            "ep": np.asarray(p.ep),
        })
        return bool(ok)

    def evaluate(self, overrides: Mapping[str, Any]) -> SearchResult:
        cols = {k: np.atleast_1d(np.asarray(v, dtype=np.float64))
                for k, v in overrides.items()}
        for k in cols:
            if k not in self._space:
                raise KeyError(f"unknown TPU config key: {k!r}")
        lengths = {v.shape[0] for v in cols.values()}
        if len(lengths) != 1:
            raise ValueError("all batched overrides must share a length")
        n = lengths.pop()
        fields = ("compute_s", "memory_s", "collective_s", "total_s",
                  "overlap_s", "valid")
        out = {f: np.zeros(n) for f in fields}
        for i in range(n):
            p = self._row_params({k: v[i] for k, v in cols.items()})
            if not self._row_valid(p):
                continue
            m = step_model(self.cfg, self.shape, p, self.costs)
            out["compute_s"][i] = m.compute_s
            out["memory_s"][i] = m.memory_s
            out["collective_s"][i] = m.collective_s
            out["total_s"][i] = m.total_s
            out["overlap_s"][i] = m.overlap_s
            out["valid"][i] = 1.0
        total = masked_total(out, self.objective)
        return SearchResult(overrides=cols, outputs=out, total_cost=total)


def _as_kwargs(p: TpuParams) -> dict:
    return {f: getattr(p, f) for f in p.__dataclass_fields__}


def mesh_space(
    n_chips: int = 256,
    micro: Sequence[int] = (1, 2, 4, 8, 16),
) -> dict[str, list[float]]:
    """Default (dp, tp, n_micro) product space for a chip budget: all dp/tp
    factorizations appear; non-factorizations are rejected by validity."""
    facs = [d for d in range(1, n_chips + 1) if n_chips % d == 0]
    return {
        "dp": [float(d) for d in facs],
        "tp": [float(n_chips // d) for d in facs],
        "n_micro": [float(m) for m in micro],
    }


def tune_tpu(
    cfg: ModelConfig,
    shape,
    *,
    n_chips: int = 256,
    space: Mapping[str, Sequence[float]] | None = None,
    costs: TpuCostFactors | None = None,
    base: TpuParams | None = None,
    objective: str = "overlap_s",
    k: int = 10,
) -> TopKResult:
    """Rank execution configs for (cfg, shape) with the shared search stack."""
    ev = TpuEvaluator(cfg, shape, costs=costs, base=base,
                      n_chips=n_chips, objective=objective)
    return search_topk(ev, space or mesh_space(n_chips),
                       k=k, exact_fallback=False)
