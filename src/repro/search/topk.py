"""Streaming top-k accumulation across evaluator blocks.

Each block is reduced on device to its k cheapest valid rows and k cheapest
invalid rows (:class:`repro.search.evaluator.BlockTopK`); this module merges
those per-block winners into one global ranking, and applies the invalid
escape hatch: when fewer than ``k`` valid configs exist, the best invalid
candidates are re-costed through the evaluator's ``exact_cost`` path (the
task-scheduler simulator for the Hadoop model) instead of reporting ``inf``.

Merging is deterministic: ties in cost resolve to the lower global index,
so streamed results agree with a full numpy ``argsort`` oracle (tested).
"""

from __future__ import annotations

import logging
from dataclasses import dataclass, field
from typing import Mapping

import numpy as np

from .evaluator import BlockTopK, Evaluator, ExactCostUnavailable, InvalidGridError

__all__ = ["TopKEntry", "TopKResult", "TopKAccumulator"]

logger = logging.getLogger("repro.search.topk")


@dataclass
class TopKEntry:
    # Offset into the streamed candidate sequence: the flat product index
    # for grid searches (usable with grid.assignment_at), the sample index
    # for random search.  `assignment` is always the authoritative config.
    index: int
    cost: float                     # seconds (exact-sim seconds if exact)
    assignment: dict[str, float]    # swept key -> value at this config
    valid: bool                     # closed-form model applicable?
    exact: bool = False             # costed via the exact simulator path


@dataclass
class TopKResult:
    entries: list[TopKEntry]        # sorted: valid by cost, then exact-costed
    k: int
    n_evaluated: int
    n_valid: int
    elapsed_s: float = 0.0
    #: why rows were invalid, summed over all streamed blocks: constraint
    #: name (repro.spec.VALIDITY_CONSTRAINTS) -> row count
    invalid_reason_counts: dict[str, int] = field(default_factory=dict)

    def best(self) -> TopKEntry:
        if not self.entries:
            raise InvalidGridError(
                "search produced no rankable configuration (no valid configs "
                "and no exact_cost escape hatch on this evaluator)"
            )
        return self.entries[0]

    @property
    def configs_per_sec(self) -> float:
        return self.n_evaluated / self.elapsed_s if self.elapsed_s > 0 else 0.0


@dataclass
class _Cands:
    """One running candidate pool (cost-ascending, ties by global index)."""

    costs: np.ndarray = field(default_factory=lambda: np.empty(0))
    gidx: np.ndarray = field(default_factory=lambda: np.empty(0, dtype=np.int64))
    assigns: list = field(default_factory=list)

    def merge(self, k: int, costs, gidx, assigns) -> None:
        allc = np.concatenate([self.costs, costs])
        alli = np.concatenate([self.gidx, gidx])
        alla = self.assigns + assigns
        finite = np.isfinite(allc)
        order = np.lexsort((alli[finite], allc[finite]))[:k]
        self.costs = allc[finite][order]
        self.gidx = alli[finite][order]
        fa = [a for a, f in zip(alla, finite) if f]
        self.assigns = [fa[i] for i in order]


class TopKAccumulator:
    """Merge per-block :class:`BlockTopK` reductions into a global top-k."""

    def __init__(self, k: int):
        self.k = k
        self._valid = _Cands()
        self._invalid = _Cands()
        self.n_evaluated = 0
        self.n_valid = 0
        self._reasons: dict[str, int] = {}

    def update(
        self, start: int, cols: Mapping[str, np.ndarray], block: BlockTopK
    ) -> None:
        """Fold one block's winners in (``start`` = its global offset)."""
        n_rows = len(next(iter(cols.values())))
        self.n_evaluated += n_rows
        self.n_valid += block.n_valid
        for name, n in block.reason_counts.items():
            self._reasons[name] = self._reasons.get(name, 0) + n

        def pick(costs, idx, pool: _Cands):
            keep = np.isfinite(costs)
            li = idx[keep]
            assigns = [
                {k: float(v[i]) for k, v in cols.items()} for i in li
            ]
            pool.merge(self.k, costs[keep], start + li.astype(np.int64), assigns)

        pick(block.costs, block.idx, self._valid)
        pick(block.inv_costs, block.inv_idx, self._invalid)

    def finalize(
        self,
        evaluator: Evaluator,
        *,
        exact_fallback: bool = True,
        elapsed_s: float = 0.0,
    ) -> TopKResult:
        """Global ranking; open slots are filled by the best invalid configs
        re-costed through ``evaluator.exact_cost`` (never silent ``inf``)."""
        entries = [
            TopKEntry(int(i), float(c), a, valid=True)
            for c, i, a in zip(self._valid.costs, self._valid.gidx,
                               self._valid.assigns)
        ]
        free = self.k - len(entries)
        if free > 0 and exact_fallback and len(self._invalid.assigns):
            logger.info(
                "valid==0 exact fallback: only %d/%d ranked rows are model-"
                "valid; re-costing up to %d invalid survivor(s) via "
                "evaluator.exact_cost; failed constraints across the grid: %s",
                len(entries), self.k, len(self._invalid.assigns),
                ", ".join(f"{n}={c}" for n, c in self._reasons.items())
                or "not reported by this backend",
            )
            survivors = []
            for c, i, a in zip(self._invalid.costs, self._invalid.gidx,
                               self._invalid.assigns):
                try:
                    exact = evaluator.exact_cost(a)
                except ExactCostUnavailable as e:
                    logger.info("exact fallback skipped row %d: %s", i, e)
                    continue            # candidate stays out of the ranking
                if exact is None:
                    break               # evaluator has no exact path
                survivors.append(TopKEntry(int(i), exact, a,
                                           valid=False, exact=True))
            survivors.sort(key=lambda e: (e.cost, e.index))
            entries.extend(survivors[:free])
        return TopKResult(
            entries=entries,
            k=self.k,
            n_evaluated=self.n_evaluated,
            n_valid=self.n_valid,
            elapsed_s=elapsed_s,
            invalid_reason_counts=dict(self._reasons),
        )
