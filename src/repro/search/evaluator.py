"""Chunked, sharded, jit-cache-friendly batched config evaluation.

The what-if engine answers the paper's questions by evaluating the analytic
job model (:func:`repro.core.hadoop.model.job_model_jnp`) over *grids* of
configurations.  The seed implementation materialized the whole grid in one
``jit(vmap(...))`` call — one compile per grid size, everything on one
device.  :class:`ChunkedEvaluator` replaces it with a streaming design:

* **Fixed-size padded chunks** — every batch is padded (edge-replicated) to
  one static ``chunk`` length, so XLA compiles exactly once per swept
  key-set no matter how the grid size varies (bounded device memory, no
  recompiles).
* **Device sharding** — each chunk is split across all available devices
  with ``shard_map`` over a 1-D ``search`` mesh (via :mod:`repro.compat`,
  which papers over the 0.4.x/0.6+ API drift).  Rows are independent, so
  the chunked/sharded results are bit-for-bit identical to the unchunked
  single-device path (asserted by tests and ``benchmarks/bench_whatif``).
* **On-device top-k** — ``chunk_topk`` reduces each chunk to its ``k`` best
  (and ``k`` best *invalid*) candidates on device, so a 10^6-config search
  transfers k values per chunk to the host instead of the whole grid.
* **Invalid-config escape hatch** — configs with ``valid == 0`` (closed-form
  merge math out of domain, paper §2.3) are *not* silently ``inf``: top-k
  survivors are routed to :meth:`exact_cost`, the task-scheduler simulator
  (:mod:`repro.core.hadoop.simulator`) whose per-task costs use the exact
  merge simulation.

The same interface is implemented by :class:`repro.search.tpu.TpuEvaluator`
for the TPU-side tuner, so every strategy in
:mod:`repro.search.strategies` runs against either cost model.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.obs import current as _obs_current
from repro.core.hadoop.model import job_model_jnp, pack_config
from repro.core.hadoop.params import CostFactors, HadoopParams, ProfileStats
from repro.core.hadoop.simulator import SimConfig, simulate_job
from repro.spec import CostReport, JobSpec, ParamSpace, hadoop_space
from repro.spec.report import VALIDITY_CONSTRAINTS

__all__ = [
    "InvalidGridError",
    "ExactCostUnavailable",
    "NotDifferentiableError",
    "SearchResult",
    "BlockTopK",
    "Evaluator",
    "ChunkedEvaluator",
    "cached_evaluator",
    "evaluate_unchunked",
    "apply_assignment",
    "split_overrides",
    "pad_block",
    "sanitize_costs",
    "masked_total",
]


class InvalidGridError(ValueError):
    """Every configuration in the evaluated grid was invalid (no finite cost)."""


class NotDifferentiableError(TypeError):
    """This backend's cost is not a differentiable function of its knobs.

    Raised by :meth:`Evaluator.grad_objective` on backends whose cost comes
    from a simulation or table lookup (the cluster DES, the numpy TPU step
    model).  Gradient-based strategies catch it and fall back — loudly — to
    a zeroth-order strategy.
    """


class ExactCostUnavailable(ValueError):
    """``exact_cost`` cannot produce a finite cost for this one candidate
    (e.g. the cluster DES reports the workload never finishes there).

    Raised instead of returning a silent ``inf``: direct callers get the
    explicit failure, while the generic fallback paths (streamed top-k,
    coordinate descent, the what-if service) catch it, log, and leave that
    candidate at ``inf`` rather than aborting a whole completed search.
    """


@dataclass
class SearchResult:
    """Batched model outputs plus the override grid that produced them."""

    overrides: dict[str, np.ndarray]    # key -> (B,) values
    outputs: dict[str, np.ndarray]      # model key -> (B,) values
    total_cost: np.ndarray              # (B,) seconds (inf where invalid)

    def best(self) -> tuple[int, float, dict[str, float]]:
        """Index, cost and override assignment of the cheapest valid config.

        Raises :class:`InvalidGridError` if no config is valid — the seed
        version silently returned index 0 (an invalid config) in that case.
        """
        if self.total_cost.size == 0 or not np.isfinite(self.total_cost).any():
            raise InvalidGridError(
                "no valid configuration in the grid (all costs are inf); "
                "use repro.search.search_topk(exact_fallback=True) to route "
                "invalid configs through the exact simulator instead"
            )
        i = int(np.argmin(self.total_cost))
        return i, float(self.total_cost[i]), {
            k: float(v[i]) for k, v in self.overrides.items()
        }


def apply_assignment(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    assignment: Mapping[str, float],
) -> tuple[HadoopParams, ProfileStats, CostFactors]:
    """Route a flat {config key: value} assignment onto the three parameter
    dataclasses with proper int/bool coercion.

    Thin adapter over :meth:`repro.spec.ParamSpace.apply` — the axis kinds
    of :func:`repro.spec.hadoop_space` are the single source of coercion.
    """
    return hadoop_space().apply(assignment, p, s, c)


def sanitize_costs(raw, xp=np):
    """NaN/±inf -> +inf, so one bad row can never win a min/top-k.

    The ONE implementation of the cost_key sanitization rule, shared by
    every evaluator's host (numpy) and device (``xp=jnp``) reductions.
    """
    return xp.nan_to_num(raw, nan=xp.inf, posinf=xp.inf, neginf=xp.inf)


def masked_total(outputs: Mapping[str, Any], cost_key: str, xp=np):
    """The canonical total-cost column: model cost where ``valid``, else inf.

    Shared by :class:`ChunkedEvaluator`, the cluster planner and the what-if
    service so the invalid-row convention cannot drift between backends.

    Gradient safety: this ``where`` zeroes the cotangent of masked rows, but
    a zero cotangent times an infinite *local* derivative upstream is still
    NaN (the classic where/inf bug).  The fix lives at the producers — the
    dangerous divisions in ``core/hadoop/model.py`` are double-``where``
    guarded and round counts use the straight-through helpers — so
    ``jax.grad`` of this masked total is finite even on invalid configs
    (regression-tested in ``tests/test_gradients.py``).  ``sanitize_costs``
    and ``topk.py`` run host-side on already-materialized numpy values and
    carry no gradients, so they need no such guard.
    """
    return xp.where(outputs["valid"] > 0, outputs[cost_key], xp.inf)


@dataclass
class BlockTopK:
    """Per-block top-k reduction: k cheapest valid rows, k cheapest invalid
    rows (candidates for the exact escape hatch), and the block valid count.
    Indices are block-local.  ``reason_counts`` says *why* rows were invalid
    (per closed-form constraint of :data:`repro.spec.VALIDITY_CONSTRAINTS`),
    for backends whose outputs expose the disaggregated flags."""

    costs: np.ndarray
    idx: np.ndarray
    inv_costs: np.ndarray
    inv_idx: np.ndarray
    n_valid: int
    reason_counts: dict[str, int] = field(default_factory=dict)


class Evaluator:
    """Interface every search backend implements.

    ``evaluate`` returns full per-config outputs; ``chunk_topk`` reduces one
    block to its best candidates; ``exact_cost`` (optional) is the escape
    hatch for ``valid == 0`` survivors.  The base class provides a numpy
    ``chunk_topk`` on top of ``evaluate``; accelerator-backed evaluators
    override it with an on-device reduction.
    """

    chunk: int = 4096

    def evaluate(self, overrides: Mapping[str, Any]) -> SearchResult:
        raise NotImplementedError

    def evaluate_small(self, overrides: Mapping[str, Any]) -> SearchResult:
        """Hook for tiny ad-hoc batches; backends with padded fixed-size
        batches override this with an unpadded path."""
        return self.evaluate(overrides)

    def exact_cost(self, assignment: Mapping[str, float]) -> float | None:
        """Exact re-cost of one assignment, ``None`` when the backend has no
        exact path.  May raise :class:`ExactCostUnavailable` for a candidate
        whose exact cost is undefined (callers in this package catch it)."""
        return None

    def report(self, overrides: Mapping[str, Any]) -> CostReport | None:
        """Typed per-phase :class:`repro.spec.CostReport` for these rows, or
        ``None`` for backends without a phase decomposition."""
        return None

    @property
    def param_space(self) -> ParamSpace | None:
        """Declarative description of this backend's searchable axes
        (:class:`repro.spec.ParamSpace`), or ``None`` if undeclared."""
        return None

    def grad_objective(self):
        """Differentiable single-config objective, for gradient strategies.

        Returns ``fn({key: jnp scalar}) -> (cost, valid)`` where ``cost`` is
        the *raw* (unmasked) model cost — differentiable w.r.t. every float
        override — and ``valid`` the model's validity flag (0/1, no useful
        gradient).  Backends whose cost is not a differentiable function of
        the knobs raise :class:`NotDifferentiableError` instead; callers
        must catch it and fall back loudly.
        """
        raise NotDifferentiableError(
            f"{type(self).__name__} does not expose a differentiable "
            "objective; use a zeroth-order strategy (grid/random/descent)"
        )

    def chunk_topk(self, overrides: Mapping[str, np.ndarray], k: int) -> "BlockTopK":
        """Top-k of one block: the k cheapest valid configs and the k
        cheapest invalid configs (ranked by raw model cost)."""
        res = self.evaluate(overrides)
        valid = res.outputs["valid"] > 0
        raw = sanitize_costs(res.outputs[self.cost_key])
        cost = np.where(valid, raw, np.inf)
        inv = np.where(~valid, raw, np.inf)
        kk = min(k, cost.size)
        idx = np.argsort(cost, kind="stable")[:kk]
        inv_idx = np.argsort(inv, kind="stable")[:kk]
        from repro.spec.report import invalid_reason_counts

        # merged cfg gates reduce-side constraints off for map-only rows,
        # matching ChunkedEvaluator._topk_body's on-device counts
        cfg = {**getattr(self, "base_cfg", {}), **overrides}
        return BlockTopK(cost[idx], idx, inv[inv_idx], inv_idx, int(valid.sum()),
                         invalid_reason_counts(res.outputs, cfg or None))

    @property
    def cost_key(self) -> str:
        return "j_totalCost"


def split_overrides(
    base_cfg: Mapping[str, Any], overrides: Mapping[str, Any]
) -> tuple[dict[str, np.ndarray], dict[str, Any], int]:
    """Validate + cast an override mapping against ``base_cfg``: 1-D values
    become batched ``(n,)`` columns sharing one length, scalars are merged
    onto the base as statics.  Each override takes ``base_cfg``'s dtype for
    its key, so service-normalized rows and direct calls see bit-identical
    inputs.  One implementation shared by every chunked evaluator (Hadoop
    job model here, cluster planner in :mod:`repro.cluster.evaluator`) so
    the contract cannot drift."""
    static = dict(base_cfg)
    batched: dict[str, np.ndarray] = {}
    n = None
    for k, v in overrides.items():
        if k not in base_cfg:
            raise KeyError(f"unknown config key: {k!r}")
        arr = jnp.asarray(v, dtype=base_cfg[k].dtype)
        if arr.ndim > 1:
            raise ValueError(f"override {k!r} must be scalar or 1-D")
        if arr.ndim == 1:
            if n is None:
                n = arr.shape[0]
            elif arr.shape[0] != n:
                raise ValueError("all batched overrides must share a length")
            batched[k] = np.asarray(arr)
        else:
            static[k] = arr
    if n is None:
        raise ValueError("at least one override must be batched")
    if n == 0:
        raise ValueError("batched overrides are empty (0-length grid)")
    return batched, static, n


def pad_block(
    batched: Mapping[str, np.ndarray], start: int, stop: int, chunk: int
) -> tuple[dict[str, np.ndarray], np.ndarray]:
    """One ``(chunk,)``-padded slice ``[start, stop)``: edge-replicated
    values + liveness mask.  Static shape => one compile per key-set for
    any grid size."""
    n = stop - start
    pad = chunk - n
    cols = {}
    for k, v in batched.items():
        sl = v[start:stop]
        cols[k] = np.concatenate([sl, np.full(pad, sl[-1], dtype=sl.dtype)]) \
            if pad else sl
    mask = np.zeros(chunk, dtype=bool)
    mask[:n] = True
    return cols, mask


def evaluate_unchunked(
    base_cfg: dict,
    overrides: Mapping[str, jnp.ndarray],
    model_fn: Callable[[dict], dict] = job_model_jnp,
) -> dict:
    """Single-device single-call ``jit(vmap(model))`` — the seed path.

    Kept as the bit-for-bit reference the chunked/sharded path is verified
    against (tests + ``bench_whatif``).  Compiles once per batch *size*.
    """
    cfg = dict(base_cfg)
    cfg.update({k: jnp.asarray(v) for k, v in overrides.items()})
    out = _unchunked_jit(model_fn)(cfg)
    return {k: np.asarray(v) for k, v in out.items()}


@functools.lru_cache(maxsize=None)
def _unchunked_jit(model_fn):
    @jax.jit
    def run(cfg: dict) -> dict:
        batched = {k: v for k, v in cfg.items() if jnp.ndim(v) > 0}
        static = {k: v for k, v in cfg.items() if jnp.ndim(v) == 0}
        return jax.vmap(lambda b: model_fn({**static, **b}))(batched)

    return run


class ChunkedEvaluator(Evaluator):
    """Streaming sharded evaluator over the Hadoop job model.

    Parameters
    ----------
    p, s, c : the base configuration (any field may be overridden per-row).
    chunk   : static rows per evaluation call (rounded up to a multiple of
              the device count).  One XLA compile per swept key-set.
    devices : devices to shard chunks over (default: all local devices).
    model_fn: batched model, flat cfg dict -> flat outputs dict; must emit
              ``j_totalCost`` and ``valid``.
    """

    def __init__(
        self,
        p: HadoopParams,
        s: ProfileStats,
        c: CostFactors,
        *,
        chunk: int = 1 << 13,
        devices=None,
        model_fn: Callable[[dict], dict] = job_model_jnp,
    ):
        self._psc = (p, s, c)
        #: typed view of the base configuration (repro.spec.JobSpec)
        self.spec = JobSpec(p, s, c)
        #: packed base config (flat key -> jnp scalar); public so callers can
        #: drive evaluate_unchunked against the exact same base
        self.base_cfg = pack_config(p, s, c)
        self._model_fn = model_fn
        devs = list(devices) if devices is not None else compat.default_search_devices()
        self.num_devices = len(devs)
        self.chunk = -(-max(chunk, 1) // self.num_devices) * self.num_devices
        self._mesh = compat.make_mesh(devs, axis="search")

        body = self._sharded_body()
        self._eval_fn = jax.jit(body)
        self._topk_fn = jax.jit(
            functools.partial(self._topk_body, body), static_argnames=("k",)
        )

    @classmethod
    def from_spec(cls, spec: JobSpec, **kw) -> "ChunkedEvaluator":
        """Construct from a typed :class:`repro.spec.JobSpec` — the typed
        spelling of ``ChunkedEvaluator(p, s, c)``, bit-for-bit identical."""
        return cls(spec.params, spec.stats, spec.costs, **kw)

    @property
    def param_space(self) -> ParamSpace:
        """The paper's Tables-1-3 axes (:func:`repro.spec.hadoop_space`)."""
        return hadoop_space()

    # ---------------- compiled bodies ----------------

    def _sharded_body(self):
        model_fn = self._model_fn
        mesh = self._mesh

        def per_device(batched, static):
            return jax.vmap(lambda b: model_fn({**static, **b}))(batched)

        return compat.shard_map(
            per_device,
            mesh=mesh,
            in_specs=(P("search"), P()),
            out_specs=P("search"),
            check_vma=False,
        )

    def _topk_body(self, body, batched, static, mask, *, k):
        out = body(batched, static)
        raw = sanitize_costs(out[self.cost_key], xp=jnp)
        live = mask > 0
        valid = (out["valid"] > 0) & live
        cost = jnp.where(valid, raw, jnp.inf)
        inv = jnp.where(~(out["valid"] > 0) & live, raw, jnp.inf)
        neg_c, idx = jax.lax.top_k(-cost, k)
        neg_i, inv_idx = jax.lax.top_k(-inv, k)
        # per-constraint invalidity counts ride the same device reduction,
        # so the escape-hatch log can say WHICH closed-form domain failed.
        # Reduce-side flags are zeroed by the model for map-only rows; gate
        # them on pNumReducers so they do not over-report there.
        has_red = (batched["pNumReducers"] if "pNumReducers" in batched
                   else static["pNumReducers"]) > 0
        reasons = {}
        for name, (key, reduce_side, _) in VALIDITY_CONSTRAINTS.items():
            if key not in out:
                continue
            failed = (out[key] == 0) & live
            if reduce_side:
                failed = failed & has_red
            reasons[name] = jnp.sum(failed)
        return -neg_c, idx, -neg_i, inv_idx, jnp.sum(valid), reasons

    # ---------------- padding / packing ----------------

    def _split(self, overrides: Mapping[str, Any]):
        """Validate + cast overrides; split into batched columns and scalar
        (static) overrides merged onto the base config."""
        return split_overrides(self.base_cfg, overrides)

    def _pad(self, batched: Mapping[str, np.ndarray], start: int, stop: int):
        """One (chunk,)-padded slice (see :func:`pad_block`)."""
        return pad_block(batched, start, stop, self.chunk)

    # ---------------- public API ----------------

    def evaluate(self, overrides: Mapping[str, Any]) -> SearchResult:
        """Full outputs for every row, streamed through fixed-size chunks.

        Bit-for-bit identical to :func:`evaluate_unchunked` on the same
        overrides (padding rows are computed but dropped here).
        """
        batched, static, n = self._split(overrides)
        ob = _obs_current()
        t0 = time.perf_counter() if ob.enabled else 0.0
        out_blocks: dict[str, list[np.ndarray]] = {}
        with ob.tracer.span("evaluator.evaluate", rows=n):
            for start in range(0, n, self.chunk):
                stop = min(start + self.chunk, n)
                cols, _ = self._pad(batched, start, stop)
                pre = self.eval_cache_size() if ob.enabled else 0
                out = self._eval_fn(cols, static)
                if ob.enabled:
                    self._note_chunk(ob, batched, pre, self.eval_cache_size())
                for k, v in out.items():
                    out_blocks.setdefault(k, []).append(
                        np.asarray(v)[: stop - start])
        if ob.enabled:
            self._note_evaluate(ob, n, time.perf_counter() - t0)
        outputs = {k: np.concatenate(v) for k, v in out_blocks.items()}
        total = masked_total(outputs, self.cost_key)
        return SearchResult(overrides=batched, outputs=outputs, total_cost=total)

    # ---------------- observability (host-side only; never inside jit) ----

    def _note_chunk(self, ob, batched, pre_compiles: int,
                    post_compiles: int) -> None:
        """Per-chunk accounting: the one-compile-per-key-set contract as a
        runtime-observable metric."""
        ob.registry.counter("evaluator.chunks").inc()
        if post_compiles > pre_compiles:
            key_set = ",".join(sorted(batched))
            ob.registry.counter("evaluator.compiles").inc()
            ob.tracer.instant("xla compile", scope="p", key_set=key_set)

    def _note_evaluate(self, ob, n: int, elapsed: float) -> None:
        n_chunks = -(-n // self.chunk)
        padded = n_chunks * self.chunk - n
        reg = ob.registry
        reg.counter("evaluator.rows").inc(n)
        reg.counter("evaluator.rows_padded").inc(padded)
        reg.histogram("evaluator.evaluate_s").record(elapsed)
        if elapsed > 0:
            ob.tracer.counter(
                "evaluator",
                configs_per_s=n / elapsed,
                padding_waste=padded / (n + padded) if n + padded else 0.0,
            )

    def report(self, overrides: Mapping[str, Any]) -> CostReport:
        """Typed per-phase report for these rows (the ``repro.api`` path).

        Evaluates through the identical chunked executable and lifts the
        flat outputs into a :class:`repro.spec.CostReport`; ``total_cost``
        and ``valid`` are the dict path's arrays by reference, so the typed
        path is bit-for-bit the dict path.
        """
        res = self.evaluate(overrides)
        cfg = {k: np.asarray(v) for k, v in self.base_cfg.items()}
        for k, v in overrides.items():
            cfg[k] = np.asarray(v, dtype=cfg[k].dtype)
        return CostReport.from_outputs(res.outputs, cfg)

    def evaluate_small(self, overrides: Mapping[str, Any]) -> SearchResult:
        """Tiny ad-hoc batches without padding to the full chunk: rows are
        padded to the next power of two instead, so compiles stay bounded
        (one per bucket) while the evaluated-row waste stays < 2x.  Batches
        at or beyond the chunk size take the normal chunked path.

        Note: for *repeated* small sweeps (coordinate descent) the chunked
        :meth:`evaluate` is usually faster end-to-end — its one executable
        is already compiled, and padded rows are cheaper than a retrace."""
        batched, static, n = self._split(overrides)
        if n >= self.chunk:
            return self.evaluate(overrides)
        bucket = 1 << (n - 1).bit_length() if n > 1 else 1
        padded = {
            k: np.concatenate([v, np.full(bucket - n, v[-1], dtype=v.dtype)])
            for k, v in batched.items()
        }
        out = evaluate_unchunked(static, padded, self._model_fn)
        out = {k: v[:n] for k, v in out.items()}
        total = masked_total(out, self.cost_key)
        return SearchResult(overrides=batched, outputs=out, total_cost=total)

    def chunk_topk(self, overrides: Mapping[str, np.ndarray], k: int) -> BlockTopK:
        """On-device top-k of one block (k cheapest valid / invalid rows);
        only 2k scalars + indices come back to the host."""
        batched, static, n = self._split(overrides)
        if n > self.chunk:
            raise ValueError(f"block of {n} rows exceeds chunk={self.chunk}")
        cols, mask = self._pad(batched, 0, n)
        kk = min(k, self.chunk)
        ob = _obs_current()
        with ob.tracer.span("evaluator.chunk_topk", rows=n, k=kk):
            pre = self.topk_cache_size() if ob.enabled else 0
            costs, idx, inv_c, inv_i, n_valid, reasons = self._topk_fn(
                cols, static, mask, k=kk)
        if ob.enabled:
            reg = ob.registry
            reg.counter("evaluator.topk_blocks").inc()
            reg.counter("evaluator.rows").inc(n)
            reg.counter("evaluator.rows_padded").inc(self.chunk - n)
            if self.topk_cache_size() > pre:
                reg.counter("evaluator.compiles").inc()
                ob.tracer.instant("xla compile", scope="p",
                                  key_set=",".join(sorted(batched)))
        return BlockTopK(
            np.asarray(costs), np.asarray(idx),
            np.asarray(inv_c), np.asarray(inv_i), int(n_valid),
            {name: int(v) for name, v in reasons.items() if int(v)},
        )

    def grad_objective(self):
        """The job model as a differentiable objective: the branch-free
        equations with straight-through round counts, evaluated on one
        config (base + scalar overrides).  Same ``model_fn`` as the chunked
        path, so the value at any point agrees with :meth:`evaluate`."""
        base = self.base_cfg
        model_fn = self._model_fn
        cost_key = self.cost_key

        def objective(overrides: Mapping[str, Any]):
            out = model_fn({**base, **overrides})
            return out[cost_key], out["valid"]

        return objective

    def exact_cost(self, assignment: Mapping[str, float]) -> float:
        """Escape hatch for ``valid == 0``: exact task-scheduler simulation
        (paper §5 way (i)); its per-task merge accounting uses the exact
        merge simulation, so it has no closed-form domain restriction."""
        p2, s2, c2 = apply_assignment(*self._psc, assignment)
        return float(simulate_job(p2, s2, c2, SimConfig()).makespan)

    # compile-cache introspection (used by tests/bench to prove chunking
    # keeps one compile across grid sizes)
    def eval_cache_size(self) -> int:
        return self._eval_fn._cache_size()

    def topk_cache_size(self) -> int:
        return self._topk_fn._cache_size()


# The parameter dataclasses are frozen (hashable), so repeated calls through
# the legacy whatif/tuner APIs with the same base config reuse one evaluator
# — and with it the compiled chunk executables, matching the seed's
# module-level jit cache.
@functools.lru_cache(maxsize=16)
def cached_evaluator(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    chunk: int | None = None,
) -> ChunkedEvaluator:
    kw = {} if chunk is None else {"chunk": chunk}
    return ChunkedEvaluator(p, s, c, **kw)
