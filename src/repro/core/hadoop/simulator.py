"""Task Scheduler Simulator (paper §5, approach (i)).

The paper offers two ways to compose task-level costs into a job-level cost:
the analytic wave formulas (Eqs. 92-98) and "simulat[ing] the task execution
using a Task Scheduler Simulator ... scheduling and simulating the execution
of individual tasks on a virtual cluster.  The cost for each task is
calculated using the proposed performance models."

This module is that simulator: a discrete-event scheduler over a virtual
cluster of ``pNumNodes`` nodes with ``pMaxMapsPerNode`` map slots and
``pMaxRedPerNode`` reduce slots per node.  Beyond the paper it also models
the mechanisms a production scheduler needs at scale — the same mechanisms
our TPU runtime mirrors (see ``repro.runtime.stragglers``):

* **slowstart**      — reducers launch once ``pReduceSlowstart`` of maps done;
* **stragglers**     — per-task multiplicative slowdowns (seeded RNG);
* **speculative execution** — Hadoop-style backup tasks for outlier maps
  *and* reduces (backup reduces are only considered once every map output
  exists, so a shuffle stalled on the map fleet is not mistaken for a
  straggler);
* **node failures**  — at a failure time, running tasks are re-queued and
  *completed map outputs on the failed node are re-executed* (Hadoop
  semantics: map output lives on local disk of the mapper).

Determinism: all randomness comes from a seeded ``random.Random``; repeated
runs with the same seed are bit-identical (tested).

The multi-job cluster simulator (:mod:`repro.cluster.sched`) extends the
same mechanics to a shared cluster of concurrent jobs.
"""

from __future__ import annotations

import heapq
import random
from collections import deque
from dataclasses import dataclass, field

from .params import CostFactors, HadoopParams, ProfileStats
from .ref import job_model

__all__ = ["SimConfig", "SimResult", "TaskRecord", "simulate_job"]


@dataclass(frozen=True)
class SimConfig:
    """Knobs of the virtual cluster beyond the paper's parameters."""

    seed: int = 0
    straggler_prob: float = 0.0          # P(task is a straggler)
    straggler_slowdown: float = 3.0      # straggler duration multiplier
    speculative_execution: bool = True
    speculative_slowdown_thr: float = 1.5  # backup if projected > thr x mean
    speculative_min_completed: int = 3   # need this many finished tasks first
    node_failures: tuple[tuple[float, int], ...] = ()  # (time_s, node_id)
    task_time_jitter: float = 0.0        # +/- uniform fraction on durations


@dataclass
class TaskRecord:
    kind: str               # "map" | "reduce"
    index: int
    node: int
    start: float
    end: float
    speculative: bool = False
    killed: bool = False


@dataclass
class SimResult:
    makespan: float
    map_finish_time: float
    records: list[TaskRecord] = field(default_factory=list)
    num_speculative_launched: int = 0
    num_speculative_won: int = 0
    num_failure_reruns: int = 0
    map_task_cost: float = 0.0
    reduce_task_cost: float = 0.0
    shuffle_time_per_reducer: float = 0.0
    # Per-node seconds a slot was occupied by a task (including killed and
    # speculative copies — the slot was held either way), and the fraction
    # of nominal slot-seconds (makespan x all configured slots) that was
    # busy.  Failed nodes keep their nominal capacity in the denominator.
    node_busy_s: list[float] = field(default_factory=list)
    slot_utilization: float = 0.0


def _duration(base: float, rng: random.Random, sc: SimConfig) -> float:
    d = base
    if sc.task_time_jitter > 0.0:
        d *= 1.0 + rng.uniform(-sc.task_time_jitter, sc.task_time_jitter)
    if sc.straggler_prob > 0.0 and rng.random() < sc.straggler_prob:
        d *= sc.straggler_slowdown
    return max(d, 1e-9)


def simulate_job(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    sim: SimConfig = SimConfig(),
) -> SimResult:
    """Simulate one MapReduce job; task costs come from the §2-§4 models."""
    jm = job_model(p, s, c)
    map_cost = jm.map.ioCost + jm.map.cpuCost
    red_cost = jm.reduce.ioCost + jm.reduce.cpuCost if p.pNumReducers else 0.0
    # Per-reducer share of the network transfer (Eqs. 90-91), serialized per
    # reducer: each reducer pulls its partition across the network.  The
    # import is deferred: repro.core cannot depend on repro.cluster at
    # module scope (repro.cluster.sched imports this module), but
    # repro.cluster.network sits below both packages.
    from repro.cluster.network import per_reducer_shuffle

    shuffle_net = per_reducer_shuffle(jm.netCost, p.pNumReducers)

    rng = random.Random(sim.seed)
    res = SimResult(
        makespan=0.0,
        map_finish_time=0.0,
        map_task_cost=map_cost,
        reduce_task_cost=red_cost,
        shuffle_time_per_reducer=shuffle_net,
    )

    n_nodes = max(1, p.pNumNodes)
    map_slots = [p.pMaxMapsPerNode] * n_nodes
    red_slots = [p.pMaxRedPerNode] * n_nodes

    # --- state ---
    # deques: the multi-thousand-task workloads of the cluster layer made
    # the old list-head pops an O(n^2) hotspot
    pending_maps = deque(range(p.pNumMappers))
    completed_maps: set[int] = set()
    map_output_node: dict[int, int] = {}
    running: dict[int, tuple[str, int, int, float, float, bool]] = {}
    # running[task_uid] = (kind, index, node, start, end, speculative)
    # Reduce tasks are two-phase: the shuffle overlaps the map fleet, but
    # sort/reduce/write can only run once ALL map outputs exist.  A reducer
    # launched before the maps finish carries end=+inf until the last map
    # completes, at which point its completion event is scheduled as
    #   end = max(last_map_time, start + shuffle) + work.
    reduce_durs: dict[int, tuple[float, float]] = {}  # uid -> (shuffle, work)
    uid_counter = 0
    # task index -> list of running uids (primary + speculative copies)
    map_copies: dict[int, list[int]] = {}
    red_copies: dict[int, list[int]] = {}
    finished_map_durations: list[float] = []
    finished_red_durations: list[float] = []

    pending_reduces = deque(range(p.pNumReducers))
    reducers_launched = False
    completed_reduces: set[int] = set()

    failures = sorted(sim.node_failures)
    fail_idx = 0

    events: list[tuple[float, int, str, int]] = []  # (time, uid, kind, index)
    clock = 0.0

    def free_slot(slots: list[int], prefer_not: int = -1) -> int:
        order = sorted(range(n_nodes), key=lambda nd: (nd == prefer_not, -slots[nd]))
        for nd in order:
            if slots[nd] > 0:
                return nd
        return -1

    def all_maps_done() -> bool:
        return len(completed_maps) == p.pNumMappers

    def launch(kind: str, index: int, now: float, *, speculative: bool = False,
               avoid_node: int = -1) -> bool:
        nonlocal uid_counter
        slots = map_slots if kind == "map" else red_slots
        node = free_slot(slots, prefer_not=avoid_node)
        if node < 0:
            return False
        slots[node] -= 1
        uid = uid_counter
        uid_counter += 1
        if kind == "map":
            dur = _duration(map_cost, rng, sim)
            end = now + dur
            running[uid] = (kind, index, node, now, end, speculative)
            map_copies.setdefault(index, []).append(uid)
            heapq.heappush(events, (end, uid, kind, index))
        else:
            sh = _duration(shuffle_net, rng, sim) if shuffle_net > 0 else 0.0
            wk = _duration(red_cost, rng, sim) if red_cost > 0 else 0.0
            reduce_durs[uid] = (sh, wk)
            red_copies.setdefault(index, []).append(uid)
            if all_maps_done():
                end = now + sh + wk
                running[uid] = (kind, index, node, now, end, speculative)
                heapq.heappush(events, (end, uid, kind, index))
            else:
                # Shuffle overlaps the maps; completion scheduled later.
                running[uid] = (kind, index, node, now, float("inf"), speculative)
        if speculative:
            res.num_speculative_launched += 1
        return True

    def schedule_waiting_reduces(now: float) -> None:
        """Last map output just landed: schedule stalled reduce completions."""
        for uid, (kind, index, node, start, end, spec) in list(running.items()):
            if kind == "reduce" and end == float("inf"):
                sh, wk = reduce_durs[uid]
                new_end = max(now, start + sh) + wk
                running[uid] = (kind, index, node, start, new_end, spec)
                heapq.heappush(events, (new_end, uid, kind, index))

    def fill_map_slots(now: float) -> None:
        while pending_maps and launch("map", pending_maps[0], now):
            pending_maps.popleft()

    def fill_reduce_slots(now: float) -> None:
        while pending_reduces and launch("reduce", pending_reduces[0], now):
            pending_reduces.popleft()

    def maybe_speculate(now: float) -> None:
        """Hadoop-style backup tasks for outliers, maps and reduces alike.

        Reduce tasks are only candidates once every map output exists: a
        first-wave reducer stalled on the map fleet looks slow without being
        a straggler, and its backup would stall the same way.
        """
        if not sim.speculative_execution:
            return

        def scan(kind, durations, completed, copies):
            if len(durations) < sim.speculative_min_completed:
                return
            mean = sum(durations) / len(durations)
            for uid, (k, index, node, start, end, spec) in list(running.items()):
                if k != kind or spec or end == float("inf"):
                    continue
                if index in completed or len(copies.get(index, [])) > 1:
                    continue
                # Measure reduces from the map-fleet finish, not their
                # launch: a first-wave reducer's shuffle stall is waiting,
                # not work, and would miscalibrate the straggler baseline.
                eff_start = start if kind == "map" \
                    else max(start, res.map_finish_time)
                projected = end - eff_start
                if projected > sim.speculative_slowdown_thr * mean and now > eff_start:
                    launch(kind, index, now, speculative=True, avoid_node=node)

        scan("map", finished_map_durations, completed_maps, map_copies)
        if all_maps_done():
            scan("reduce", finished_red_durations, completed_reduces, red_copies)

    fill_map_slots(0.0)

    while events:
        # Apply any node failure that occurs before the next event.
        next_time = events[0][0]
        if fail_idx < len(failures) and failures[fail_idx][0] <= next_time:
            ftime, fnode = failures[fail_idx]
            fail_idx += 1
            clock = max(clock, ftime)
            # Kill running tasks on the failed node; re-queue them.
            for uid, (kind, index, node, start, end, spec) in list(running.items()):
                if node != fnode:
                    continue
                del running[uid]
                reduce_durs.pop(uid, None)   # killed copy: drop its draws
                copies = map_copies if kind == "map" else red_copies
                if uid in copies.get(index, []):
                    copies[index].remove(uid)
                res.records.append(
                    TaskRecord(kind, index, node, start, ftime, spec, killed=True)
                )
                if kind == "map":
                    if index not in completed_maps and index not in pending_maps:
                        pending_maps.append(index)
                else:
                    if index not in completed_reduces and index not in pending_reduces:
                        pending_reduces.append(index)
                res.num_failure_reruns += 1
            # Completed map outputs on the failed node are lost -> re-run
            # (only matters while reducers still need them).
            if len(completed_reduces) < p.pNumReducers:
                for midx, mnode in list(map_output_node.items()):
                    if mnode == fnode and midx in completed_maps:
                        completed_maps.discard(midx)
                        del map_output_node[midx]
                        if midx not in pending_maps:
                            pending_maps.append(midx)
                        res.num_failure_reruns += 1
            # Slots on a failed node stay unusable.
            map_slots[fnode] = 0
            red_slots[fnode] = 0
            fill_map_slots(clock)
            if reducers_launched:   # a failure must not bypass slowstart
                fill_reduce_slots(clock)
            continue

        t, uid, kind, index = heapq.heappop(events)
        if uid not in running:
            continue  # stale event (task killed by failure or lost the race)
        if running[uid][4] != t:
            continue  # superseded event (reduce end was rescheduled)
        clock = t
        if kind == "reduce" and not all_maps_done():
            # A failure resurrected map work after this reduce was scheduled;
            # stall until the re-executed maps land.
            k2, i2, n2, s2, _e2, sp2 = running[uid]
            running[uid] = (k2, i2, n2, s2, float("inf"), sp2)
            continue
        kind, index, node, start, end, spec = running.pop(uid)
        res.records.append(TaskRecord(kind, index, node, start, end, spec))

        if kind == "map":
            map_slots[node] += 1
            # First copy to finish wins; kill the sibling copies.
            if index not in completed_maps:
                completed_maps.add(index)
                map_output_node[index] = node
                finished_map_durations.append(end - start)
                if spec:
                    res.num_speculative_won += 1
                for sib in map_copies.get(index, []):
                    if sib != uid and sib in running:
                        k2, i2, n2, s2, e2, sp2 = running.pop(sib)
                        map_slots[n2] += 1
                        res.records.append(
                            TaskRecord(k2, i2, n2, s2, clock, sp2, killed=True)
                        )
                map_copies[index] = []
            res.map_finish_time = max(res.map_finish_time, clock)
            if (
                not reducers_launched
                and p.pNumMappers > 0
                and len(completed_maps) >= p.pReduceSlowstart * p.pNumMappers
            ):
                reducers_launched = True
            fill_map_slots(clock)
            if reducers_launched:
                fill_reduce_slots(clock)
            if all_maps_done() and not pending_maps:
                schedule_waiting_reduces(clock)
            maybe_speculate(clock)
        else:
            red_slots[node] += 1
            reduce_durs.pop(uid, None)
            # First copy to finish wins; kill the sibling backups.
            if index not in completed_reduces:
                completed_reduces.add(index)
                # stall-free duration (see maybe_speculate)
                finished_red_durations.append(
                    end - max(start, res.map_finish_time))
                if spec:
                    res.num_speculative_won += 1
                for sib in red_copies.get(index, []):
                    if sib != uid and sib in running:
                        k2, i2, n2, s2, e2, sp2 = running.pop(sib)
                        reduce_durs.pop(sib, None)
                        red_slots[n2] += 1
                        res.records.append(
                            TaskRecord(k2, i2, n2, s2, clock, sp2, killed=True)
                        )
                red_copies[index] = []
            fill_reduce_slots(clock)
            maybe_speculate(clock)

        res.makespan = max(res.makespan, clock)

    # drift guard for the reduce_durs bookkeeping: an entry must not outlive
    # its running task (entries used to leak for the life of the simulation
    # on every failure-kill and speculative-sibling kill)
    assert set(reduce_durs) == {
        u for u, v in running.items() if v[0] == "reduce"
    }, "reduce_durs leaked entries for dead tasks"

    # --- slot-occupancy summary (consumed by the cluster layer) ---
    res.node_busy_s = [0.0] * n_nodes
    for rec in res.records:
        res.node_busy_s[rec.node] += rec.end - rec.start
    slot_seconds = res.makespan * n_nodes * (p.pMaxMapsPerNode + p.pMaxRedPerNode)
    if slot_seconds > 0:
        res.slot_utilization = sum(res.node_busy_s) / slot_seconds

    return res
