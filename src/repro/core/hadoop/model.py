"""JAX implementation of the Hadoop performance models (vectorizable).

Branch-free ``jnp.where`` formulation of exactly the same equations as
:mod:`repro.core.hadoop.ref` — the pure-Python oracle — so that the what-if
engine can ``jax.vmap`` the whole-job model over *grids of configurations*
(~10^5-10^6 configs per call) and the tuner can run on-device.

Equivalence with the oracle is property-tested in
``tests/test_model_equivalence.py`` (hypothesis drives random configurations
through both implementations).

Inputs are a flat ``dict[str, jnp.ndarray]`` produced by :func:`pack_config`;
every leaf may be a scalar or a batched array (all batched leaves must share
a shape).  Outputs are a flat dict of model quantities, prefixed ``m_`` (map
task), ``r_`` (reduce task) and ``j_`` (job level).

Validity: the closed-form merge math requires ``N <= pSortFactor**2``
(paper §2.3).  The output key ``valid`` is 1.0 where every merge-math
application was within the closed-form domain; the what-if engine masks or
penalizes configurations with ``valid == 0`` (the scalar oracle falls back to
exact simulation instead).  The three underlying constraints are also
emitted separately (``m_mergeValid``, ``r_step2Valid``, ``r_step3Valid``)
so the typed layer can say *which* one failed.

Gradients: every spill/merge round count goes through the straight-through
helpers (:func:`repro.core.hadoop.merge_math.ste_floor` / ``ste_ceil``) —
forward values are bit-for-bit ``jnp.floor``/``jnp.ceil``, but the cotangent
passes through, so ``jax.grad`` of any output w.r.t. the Table-1/2/3 inputs
is non-degenerate.  This is what :mod:`repro.calib` (cost-factor
calibration) and the ``gradient_descent_ev`` search strategy differentiate.

The typed view of this module lives in :mod:`repro.spec`:
:meth:`repro.spec.JobSpec.pack` produces the input dict (it IS
:func:`pack_config`), and :meth:`repro.spec.CostReport.from_outputs` lifts
the flat output dict into per-phase dataclasses carrying the paper
equation numbers — bit-for-bit, the aggregates are these outputs by
reference.
"""

from __future__ import annotations

import jax.numpy as jnp

from .merge_math import ste_ceil, ste_floor
from .params import MiB, CostFactors, HadoopParams, ProfileStats

__all__ = ["pack_config", "job_model_jnp", "CONFIG_KEYS"]

_P_KEYS = [f.name for f in HadoopParams.__dataclass_fields__.values()]
_S_KEYS = [f.name for f in ProfileStats.__dataclass_fields__.values()]
_C_KEYS = [f.name for f in CostFactors.__dataclass_fields__.values()]
CONFIG_KEYS = _P_KEYS + _S_KEYS + _C_KEYS


def pack_config(
    p: HadoopParams, s: ProfileStats, c: CostFactors
) -> dict[str, jnp.ndarray]:
    """Flatten the three parameter dataclasses into a dict of float arrays.

    Booleans become 0.0/1.0 so every field is overridable with a batched
    array for grid evaluation (e.g. ``cfg["pSortMB"] = jnp.linspace(...)``).
    """
    cfg = {}
    # strong-typed scalars: bare asarray(float) is weak-typed, which makes
    # the compile key differ between scalar defaults and batched override
    # columns (flagged by repro.analysis recompile-hazard)
    fdt = jnp.result_type(float)
    for src in (p, s, c):
        for k in src.__dataclass_fields__:
            cfg[k] = jnp.asarray(float(getattr(src, k)), dtype=fdt)
    return cfg


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def _initializations(cfg: dict) -> dict:
    """The paper's Initializations block, branch-free."""
    c = dict(cfg)
    use_comb = cfg["pUseCombine"] > 0
    in_comp = cfg["pIsInCompressed"] > 0
    im_comp = cfg["pIsIntermCompressed"] > 0
    out_comp = cfg["pIsOutCompressed"] > 0
    one = jnp.asarray(1.0)
    zero = jnp.asarray(0.0)
    c["sCombineSizeSel"] = jnp.where(use_comb, cfg["sCombineSizeSel"], one)
    c["sCombinePairsSel"] = jnp.where(use_comb, cfg["sCombinePairsSel"], one)
    c["cCombineCPUCost"] = jnp.where(use_comb, cfg["cCombineCPUCost"], zero)
    c["sInputCompressRatio"] = jnp.where(in_comp, cfg["sInputCompressRatio"], one)
    c["cInUncomprCPUCost"] = jnp.where(in_comp, cfg["cInUncomprCPUCost"], zero)
    c["sIntermCompressRatio"] = jnp.where(im_comp, cfg["sIntermCompressRatio"], one)
    c["cIntermUncomprCPUCost"] = jnp.where(im_comp, cfg["cIntermUncomprCPUCost"], zero)
    c["cIntermComprCPUCost"] = jnp.where(im_comp, cfg["cIntermComprCPUCost"], zero)
    c["sOutCompressRatio"] = jnp.where(out_comp, cfg["sOutCompressRatio"], one)
    c["cOutComprCPUCost"] = jnp.where(out_comp, cfg["cOutComprCPUCost"], zero)
    return c


def _first_pass(n, f):
    """Eq. 20, branch-free."""
    mod = jnp.mod(n - 1.0, f - 1.0)
    gt = jnp.where(mod == 0.0, f, mod + 1.0)
    return jnp.where(n <= f, n, gt)


def _interm_merge(n, f):
    """Eq. 21, branch-free (valid for n <= f**2)."""
    p = _first_pass(n, f)
    return jnp.where(n <= f, 0.0, p + ste_floor((n - p) / f) * f)


def _final_merge(n, f):
    """Eq. 22, branch-free (valid for n <= f**2)."""
    p = _first_pass(n, f)
    s = _interm_merge(n, f)
    return jnp.where(n <= f, n, 1.0 + ste_floor((n - p) / f) + (n - s))


def _num_passes(n, f):
    """Eq. 25, branch-free (valid for n <= f**2)."""
    p = _first_pass(n, f)
    many = 2.0 + ste_floor((n - p) / f)
    return jnp.where(n <= 1.0, 0.0, jnp.where(n <= f, 1.0, many))


def _masked_div(num, den, ok):
    """``num / den`` where ``ok``, ``+inf`` elsewhere — double-``where`` form.

    The inner ``where`` means the division never sees the degenerate
    denominator, so its local derivative is finite and the masked-out
    cotangent is exactly 0 rather than 0 * inf = nan.  The forward value is
    identical to the bare ``where(ok, num / den, inf)``.
    """
    return jnp.where(ok, num / jnp.where(ok, den, 1.0), jnp.inf)


# --------------------------------------------------------------------------
# §2 — map task, branch-free
# --------------------------------------------------------------------------


def _map_model(cfg: dict) -> dict:
    o: dict = {}
    has_red = cfg["pNumReducers"] > 0
    red = jnp.maximum(cfg["pNumReducers"], 1.0)  # div guard; selected out below
    F = cfg["pSortFactor"]

    o["inputMapSize"] = cfg["pSplitSize"] / cfg["sInputCompressRatio"]     # Eq. 2
    o["inputMapPairs"] = o["inputMapSize"] / cfg["sInputPairWidth"]        # Eq. 3
    o["ioReadCost"] = cfg["pSplitSize"] * cfg["cHdfsReadCost"]
    o["cpuReadCost"] = (
        cfg["pSplitSize"] * cfg["cInUncomprCPUCost"]
        + o["inputMapPairs"] * cfg["cMapCPUCost"]                          # Eq. 4
    )

    o["outMapSize"] = o["inputMapSize"] * cfg["sMapSizeSel"]               # Eq. 5/8
    o["outMapPairs"] = o["inputMapPairs"] * cfg["sMapPairsSel"]            # Eq. 9
    o["outPairWidth"] = o["outMapSize"] / o["outMapPairs"]                 # Eq. 10

    # Map-only branch (Eqs. 6-7).
    io_mapwrite = o["outMapSize"] * cfg["sOutCompressRatio"] * cfg["cHdfsWriteCost"]
    cpu_mapwrite = o["outMapSize"] * cfg["cOutComprCPUCost"]

    # Collect/Spill (Eqs. 11-19).
    # At degenerate profiles (sMapSizeSel -> 0) the pair width is 0 and this
    # division is +inf; _masked_div's double-where keeps the forward value
    # (inf where the mask fails) while the masked-out cotangent stays 0
    # instead of 0 * inf = nan.
    w_ok = o["outPairWidth"] > 0.0
    ser_num = cfg["pSortMB"] * MiB * (1.0 - cfg["pSortRecPerc"]) * cfg["pSpillPerc"]
    o["maxSerPairs"] = ste_floor(_masked_div(ser_num, o["outPairWidth"], w_ok))
    o["maxAccPairs"] = ste_floor(
        cfg["pSortMB"] * MiB * cfg["pSortRecPerc"] * cfg["pSpillPerc"] / 16.0
    )
    o["spillBufferPairs"] = jnp.maximum(
        1.0,
        jnp.minimum(jnp.minimum(o["maxSerPairs"], o["maxAccPairs"]), o["outMapPairs"]),
    )                                                                      # Eq. 13
    o["spillBufferSize"] = o["spillBufferPairs"] * o["outPairWidth"]       # Eq. 14
    o["numSpills"] = ste_ceil(o["outMapPairs"] / o["spillBufferPairs"])    # Eq. 15
    o["spillFilePairs"] = o["spillBufferPairs"] * cfg["sCombinePairsSel"]  # Eq. 16
    o["spillFileSize"] = (
        o["spillBufferSize"] * cfg["sCombineSizeSel"] * cfg["sIntermCompressRatio"]
    )                                                                      # Eq. 17

    io_spill = o["numSpills"] * o["spillFileSize"] * cfg["cLocalIOCost"]   # Eq. 18
    sort_depth = jnp.maximum(0.0, jnp.log2(o["spillBufferPairs"] / red))
    cpu_spill = o["numSpills"] * (                                         # Eq. 19
        o["spillBufferPairs"] * cfg["cPartitionCPUCost"]
        + o["spillBufferPairs"] * cfg["cSerdeCPUCost"]
        + o["spillBufferPairs"] * sort_depth * cfg["cSortCPUCost"]
        + o["spillBufferPairs"] * cfg["cCombineCPUCost"]
        + o["spillBufferSize"] * cfg["sCombineSizeSel"] * cfg["cIntermComprCPUCost"]
    )

    # Merge (Eqs. 20-32), closed forms.
    N = o["numSpills"]
    o["numSpillsFirstPass"] = _first_pass(N, F)                            # Eq. 23
    o["numSpillsIntermMerge"] = _interm_merge(N, F)                        # Eq. 24
    o["numMergePasses"] = _num_passes(N, F)                                # Eq. 25
    o["numSpillsFinalMerge"] = _final_merge(N, F)                          # Eq. 26
    o["mergeValid"] = (N <= F * F).astype(N.dtype)

    o["numRecSpilled"] = o["spillFilePairs"] * (                           # Eq. 27
        N + o["numSpillsIntermMerge"] + N * cfg["sCombinePairsSel"]
    )

    use_comb_merge = (                                                     # Eq. 28
        (N > 1.0)
        & (cfg["pUseCombine"] > 0)
        & (o["numSpillsFinalMerge"] >= cfg["pNumSpillsForComb"])
    )
    comb_size = jnp.where(use_comb_merge, cfg["sCombineSizeSel"], 1.0)
    comb_pairs = jnp.where(use_comb_merge, cfg["sCombinePairsSel"], 1.0)
    o["useCombInMerge"] = use_comb_merge.astype(N.dtype)
    o["intermDataSize"] = N * o["spillFileSize"] * comb_size               # Eq. 29
    o["intermDataPairs"] = N * o["spillFilePairs"] * comb_pairs            # Eq. 30

    S = o["numSpillsIntermMerge"]
    io_merge = jnp.where(                                                  # Eq. 31
        N > 1.0,
        2.0 * S * o["spillFileSize"] * cfg["cLocalIOCost"]
        + N * o["spillFileSize"] * cfg["cLocalIOCost"]
        + o["intermDataSize"] * cfg["cLocalIOCost"],
        0.0,
    )
    cpu_merge = jnp.where(                                                 # Eq. 32
        N > 1.0,
        S
        * (
            o["spillFileSize"] * cfg["cIntermUncomprCPUCost"]
            + o["spillFilePairs"] * cfg["cMergeCPUCost"]
            + (o["spillFileSize"] / cfg["sIntermCompressRatio"])
            * cfg["cIntermComprCPUCost"]
        )
        + N
        * (
            o["spillFileSize"] * cfg["cIntermUncomprCPUCost"]
            + o["spillFilePairs"] * cfg["cMergeCPUCost"]
            + o["spillFilePairs"] * cfg["cCombineCPUCost"]
        )
        + (o["intermDataSize"] / cfg["sIntermCompressRatio"])
        * cfg["cIntermComprCPUCost"],
        0.0,
    )

    # Map-only jobs emit map output straight to HDFS.
    o["intermDataSize"] = jnp.where(has_red, o["intermDataSize"], o["outMapSize"])
    o["intermDataPairs"] = jnp.where(has_red, o["intermDataPairs"], o["outMapPairs"])

    o["ioSpillCost"] = jnp.where(has_red, io_spill, 0.0)
    o["cpuSpillCost"] = jnp.where(has_red, cpu_spill, 0.0)
    o["ioMergeCost"] = jnp.where(has_red, io_merge, 0.0)
    o["cpuMergeCost"] = jnp.where(has_red, cpu_merge, 0.0)
    o["ioMapWriteCost"] = jnp.where(has_red, 0.0, io_mapwrite)
    o["cpuMapWriteCost"] = jnp.where(has_red, 0.0, cpu_mapwrite)

    o["ioCost"] = jnp.where(                                               # Eq. 33
        has_red,
        o["ioReadCost"] + io_spill + io_merge,
        o["ioReadCost"] + io_mapwrite,
    )
    o["cpuCost"] = jnp.where(                                              # Eq. 34
        has_red,
        o["cpuReadCost"] + cpu_spill + cpu_merge,
        o["cpuReadCost"] + cpu_mapwrite,
    )
    return o


# --------------------------------------------------------------------------
# §3 — reduce task, branch-free
# --------------------------------------------------------------------------


def _reduce_model(cfg: dict, m: dict) -> dict:
    o: dict = {}
    F = cfg["pSortFactor"]
    red = jnp.maximum(cfg["pNumReducers"], 1.0)
    M = cfg["pNumMappers"]

    o["segmentComprSize"] = m["intermDataSize"] / red                      # Eq. 35
    o["segmentUncomprSize"] = (
        o["segmentComprSize"] / cfg["sIntermCompressRatio"]
    )                                                                      # Eq. 36
    o["segmentPairs"] = m["intermDataPairs"] / red                         # Eq. 37
    o["totalShuffleSize"] = M * o["segmentComprSize"]                      # Eq. 38
    o["totalShufflePairs"] = M * o["segmentPairs"]                         # Eq. 39
    o["shuffleBufferSize"] = cfg["pShuffleInBufPerc"] * cfg["pTaskMem"]    # Eq. 40
    o["mergeSizeThr"] = cfg["pShuffleMergePerc"] * o["shuffleBufferSize"]  # Eq. 41

    in_mem = o["segmentUncomprSize"] < 0.25 * o["shuffleBufferSize"]
    o["inMemCase"] = in_mem.astype(M.dtype)

    # Case 1 (Eqs. 42-47)
    nseg_raw = o["mergeSizeThr"] / jnp.maximum(o["segmentUncomprSize"], 1e-30)
    nseg_c = ste_ceil(nseg_raw)
    nseg1 = jnp.where(
        nseg_c * o["segmentUncomprSize"] <= o["shuffleBufferSize"],
        nseg_c,
        ste_floor(nseg_raw),
    )
    nseg1 = jnp.maximum(1.0, jnp.minimum(nseg1, cfg["pInMemMergeThr"]))

    nseg = jnp.where(in_mem, nseg1, 1.0)                                   # Eq. 48
    o["numSegInShuffleFile"] = nseg
    o["shuffleFileSize"] = jnp.where(                                      # Eq. 44/49
        in_mem, nseg * o["segmentComprSize"] * cfg["sCombineSizeSel"],
        o["segmentComprSize"],
    )
    o["shuffleFilePairs"] = jnp.where(                                     # Eq. 45/50
        in_mem, nseg * o["segmentPairs"] * cfg["sCombinePairsSel"],
        o["segmentPairs"],
    )
    o["numShuffleFiles"] = jnp.where(in_mem, ste_floor(M / nseg), M)       # Eq. 46/51
    o["numSegmentsInMem"] = jnp.where(                                     # Eq. 47/52
        in_mem, M - nseg * ste_floor(M / nseg), 0.0
    )

    # Disk merges during shuffle (Eqs. 53-59).
    nsf = o["numShuffleFiles"]
    o["numShuffleMerges"] = jnp.where(                                     # Eq. 53
        nsf < 2.0 * F - 1.0,
        0.0,
        ste_floor((nsf - 2.0 * F + 1.0) / F) + 1.0,
    )
    o["numMergShufFiles"] = o["numShuffleMerges"]                          # Eq. 54
    o["mergShufFileSize"] = F * o["shuffleFileSize"]                       # Eq. 55
    o["mergShufFilePairs"] = F * o["shuffleFilePairs"]                     # Eq. 56
    o["numUnmergShufFiles"] = nsf - F * o["numShuffleMerges"]              # Eq. 57
    o["unmergShufFileSize"] = o["shuffleFileSize"]                         # Eq. 58
    o["unmergShufFilePairs"] = o["shuffleFilePairs"]                       # Eq. 59

    o["ioShuffleCost"] = (                                                 # Eq. 60
        nsf * o["shuffleFileSize"] * cfg["cLocalIOCost"]
        + o["numMergShufFiles"] * o["mergShufFileSize"] * 2.0 * cfg["cLocalIOCost"]
    )
    in_mem_term = (                                                        # Eq. 61
        o["totalShuffleSize"] * cfg["cIntermUncomprCPUCost"]
        + nsf * o["shuffleFilePairs"] * cfg["cMergeCPUCost"]
        + nsf * o["shuffleFilePairs"] * cfg["cCombineCPUCost"]
        + nsf
        * (o["shuffleFileSize"] / cfg["sIntermCompressRatio"])
        * cfg["cIntermComprCPUCost"]
    )
    o["cpuShuffleCost"] = (
        jnp.where(in_mem, in_mem_term, 0.0)
        + o["numMergShufFiles"] * o["mergShufFileSize"] * cfg["cIntermUncomprCPUCost"]
        + o["numMergShufFiles"] * o["mergShufFilePairs"] * cfg["cMergeCPUCost"]
        + o["numMergShufFiles"]
        * (o["mergShufFileSize"] / cfg["sIntermCompressRatio"])
        * cfg["cIntermComprCPUCost"]
    )

    # Sort/Merge — Step 1 (Eqs. 62-67).
    o["maxSegmentBuffer"] = cfg["pReducerInBufPerc"] * cfg["pTaskMem"]     # Eq. 62
    o["currSegmentBuffer"] = o["numSegmentsInMem"] * o["segmentUncomprSize"]
    o["numSegmentsEvicted"] = jnp.where(                                   # Eq. 64
        o["currSegmentBuffer"] > o["maxSegmentBuffer"],
        ste_ceil(
            (o["currSegmentBuffer"] - o["maxSegmentBuffer"])
            / jnp.maximum(o["segmentUncomprSize"], 1e-30)
        ),
        0.0,
    )
    o["numSegmentsRemainMem"] = o["numSegmentsInMem"] - o["numSegmentsEvicted"]
    o["numFilesOnDisk"] = o["numMergShufFiles"] + o["numUnmergShufFiles"]  # Eq. 66

    few_disk = o["numFilesOnDisk"] < F                                     # Eq. 67
    o["numFilesFromMem"] = jnp.where(few_disk, 1.0, o["numSegmentsEvicted"])
    o["filesFromMemSize"] = jnp.where(
        few_disk,
        o["numSegmentsEvicted"] * o["segmentComprSize"],
        o["segmentComprSize"],
    )
    o["filesFromMemPairs"] = jnp.where(
        few_disk,
        o["numSegmentsEvicted"] * o["segmentPairs"],
        o["segmentPairs"],
    )
    o["step1MergingSize"] = jnp.where(few_disk, o["filesFromMemSize"], 0.0)
    o["step1MergingPairs"] = jnp.where(few_disk, o["filesFromMemPairs"], 0.0)

    o["filesToMergeStep2"] = o["numFilesOnDisk"] + o["numFilesFromMem"]    # Eq. 68

    # Step 2 (Eqs. 69-72).
    n2 = o["filesToMergeStep2"]
    has_disk = o["numFilesOnDisk"] > 0.0
    interm2 = _interm_merge(n2, F)                                         # Eq. 69
    ratio2 = interm2 / jnp.maximum(n2, 1e-30)
    pool_size = (
        o["numMergShufFiles"] * o["mergShufFileSize"]
        + o["numUnmergShufFiles"] * o["unmergShufFileSize"]
        + o["numFilesFromMem"] * o["filesFromMemSize"]
    )
    pool_pairs = (
        o["numMergShufFiles"] * o["mergShufFilePairs"]
        + o["numUnmergShufFiles"] * o["unmergShufFilePairs"]
        + o["numFilesFromMem"] * o["filesFromMemPairs"]
    )
    o["step2MergingSize"] = jnp.where(has_disk, ratio2 * pool_size, 0.0)   # Eq. 70
    o["step2MergingPairs"] = jnp.where(has_disk, ratio2 * pool_pairs, 0.0)  # Eq. 71
    o["filesRemainFromStep2"] = jnp.where(has_disk, _final_merge(n2, F), n2)
    o["step2Valid"] = (n2 <= F * F).astype(M.dtype)

    # Step 3 (Eqs. 73-77).
    n3 = o["filesRemainFromStep2"] + o["numSegmentsRemainMem"]             # Eq. 73
    o["filesToMergeStep3"] = n3
    interm3 = _interm_merge(n3, F)                                         # Eq. 74
    ratio3 = jnp.where(n3 > 0.0, interm3 / jnp.maximum(n3, 1e-30), 0.0)
    o["step3MergingSize"] = ratio3 * o["totalShuffleSize"]                 # Eq. 75
    o["step3MergingPairs"] = ratio3 * o["totalShufflePairs"]               # Eq. 76
    o["filesRemainFromStep3"] = jnp.where(n3 > 0.0, _final_merge(n3, F), 0.0)
    o["step3Valid"] = (n3 <= F * F).astype(M.dtype)

    o["totalMergingSize"] = (                                              # Eq. 78
        o["step1MergingSize"] + o["step2MergingSize"] + o["step3MergingSize"]
    )
    o["totalMergingPairs"] = (
        o["step1MergingPairs"] + o["step2MergingPairs"] + o["step3MergingPairs"]
    )
    o["ioSortCost"] = o["totalMergingSize"] * cfg["cLocalIOCost"]          # Eq. 79
    o["cpuSortCost"] = (                                                   # Eq. 80
        o["totalMergingPairs"] * cfg["cMergeCPUCost"]
        + (o["totalMergingSize"] / cfg["sIntermCompressRatio"])
        * cfg["cIntermComprCPUCost"]
        + (o["step2MergingSize"] + o["step3MergingSize"])
        * cfg["cIntermUncomprCPUCost"]
    )

    # Reduce + Write (Eqs. 81-87).
    o["inReduceSize"] = (                                                  # Eq. 81
        nsf * o["shuffleFileSize"] / cfg["sIntermCompressRatio"]
        + o["numSegmentsInMem"] * o["segmentComprSize"] / cfg["sIntermCompressRatio"]
    )
    o["inReducePairs"] = (                                                 # Eq. 82
        nsf * o["shuffleFilePairs"] + o["numSegmentsInMem"] * o["segmentPairs"]
    )
    o["outReduceSize"] = o["inReduceSize"] * cfg["sReduceSizeSel"]         # Eq. 83
    o["outReducePairs"] = o["inReducePairs"] * cfg["sReducePairsSel"]      # Eq. 84
    o["inRedDiskSize"] = (                                                 # Eq. 85
        o["numMergShufFiles"] * o["mergShufFileSize"]
        + o["numUnmergShufFiles"] * o["unmergShufFileSize"]
        + o["numFilesFromMem"] * o["filesFromMemSize"]
    )
    o["ioWriteCost"] = (                                                   # Eq. 86
        o["inRedDiskSize"] * cfg["cLocalIOCost"]
        + o["outReduceSize"] * cfg["sOutCompressRatio"] * cfg["cHdfsWriteCost"]
    )
    o["cpuWriteCost"] = (                                                  # Eq. 87
        o["inReducePairs"] * cfg["cReduceCPUCost"]
        + o["inRedDiskSize"] * cfg["cIntermUncomprCPUCost"]
        + o["outReduceSize"] * cfg["cOutComprCPUCost"]
    )

    o["ioCost"] = o["ioShuffleCost"] + o["ioSortCost"] + o["ioWriteCost"]  # Eq. 88
    o["cpuCost"] = o["cpuShuffleCost"] + o["cpuSortCost"] + o["cpuWriteCost"]
    return o


# --------------------------------------------------------------------------
# §4 + §5 — network and job level
# --------------------------------------------------------------------------


def job_model_jnp(cfg: dict) -> dict:
    """Whole-job analytic model (Eqs. 92-98); vmap-able over batched leaves."""
    cfg = _initializations(cfg)
    has_red = cfg["pNumReducers"] > 0

    m = _map_model(cfg)
    out = {f"m_{k}": v for k, v in m.items()}

    r = _reduce_model(cfg, m)
    # Zero out the reduce side of map-only jobs.
    zero = jnp.asarray(0.0)
    for k, v in r.items():
        out[f"r_{k}"] = jnp.where(has_red, v, zero)

    map_slots = cfg["pNumNodes"] * cfg["pMaxMapsPerNode"]
    red_slots = cfg["pNumNodes"] * cfg["pMaxRedPerNode"]
    out["j_ioAllMaps"] = cfg["pNumMappers"] * m["ioCost"] / map_slots      # Eq. 92
    out["j_cpuAllMaps"] = cfg["pNumMappers"] * m["cpuCost"] / map_slots    # Eq. 93
    out["j_ioAllReducers"] = jnp.where(                                    # Eq. 94
        has_red, cfg["pNumReducers"] * r["ioCost"] / red_slots, zero
    )
    out["j_cpuAllReducers"] = jnp.where(                                   # Eq. 95
        has_red, cfg["pNumReducers"] * r["cpuCost"] / red_slots, zero
    )

    frac = (cfg["pNumNodes"] - 1.0) / cfg["pNumNodes"]
    net_size = m["intermDataSize"] * cfg["pNumMappers"] * frac             # Eq. 90
    out["j_netTransferSize"] = jnp.where(has_red, net_size, zero)
    out["j_netCost"] = out["j_netTransferSize"] * cfg["cNetworkCost"]      # Eq. 91
    if "pNumRacks" in cfg:
        # topology hook: Eq. 91 priced a flat network; with declared racks
        # the transfer runs at the incast-contended effective bandwidth of
        # repro.cluster.network (pNumReducers concurrent flows unless the
        # caller supplies nFlows).  Deferred import — repro.core cannot
        # depend on repro.cluster at module scope; network sits below both.
        from repro.cluster.network import effective_bandwidth

        bw = effective_bandwidth(
            cfg["pNumRacks"],
            cfg.get("crossRackBw", jnp.asarray(jnp.inf)),
            cfg.get("oversubscription", jnp.asarray(1.0)),
            cfg.get("nFlows", cfg["pNumReducers"]),
        )
        # double-where: bw > 0 always (it is clamped to (0, 1]), but a
        # where-guarded divide keeps the gradient NaN-free at bw -> 0
        bw_ok = bw > 0.0
        bw_safe = jnp.where(bw_ok, bw, 1.0)
        out["j_netCost"] = jnp.where(
            bw_ok, out["j_netCost"] / bw_safe, out["j_netCost"])

    out["j_ioJobCost"] = out["j_ioAllMaps"] + out["j_ioAllReducers"]       # Eq. 96
    out["j_cpuJobCost"] = out["j_cpuAllMaps"] + out["j_cpuAllReducers"]    # Eq. 97
    out["j_totalCost"] = (
        out["j_ioJobCost"] + out["j_cpuJobCost"] + out["j_netCost"]
    )                                                                      # Eq. 98

    out["valid"] = (
        m["mergeValid"]
        * jnp.where(has_red, r["step2Valid"] * r["step3Valid"], 1.0)
    )
    return out
