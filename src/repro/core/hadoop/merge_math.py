"""Merge-round mathematics (paper §2.3, Eqs. 20-22).

Hadoop merges ``N`` sorted spill files with an external multi-pass merge of
fan-in ``F`` (= ``io.sort.factor``).  Hadoop sizes the *first* pass so that all
subsequent intermediate passes merge exactly ``F`` files.  The paper gives
closed forms valid for ``N <= F**2`` and prescribes a simulation-based
approach beyond that; both are implemented here and cross-checked in tests.

Terminology (paper's):
* ``first pass``    — merges ``calc_num_spills_first_pass(N, F)`` files.
* ``intermediate``  — every pass except the final one; the paper's
  ``calcNumSpillsIntermMerge`` counts the number of *spill-file equivalents
  read* during the first + intermediate passes.
* ``final merge``   — merges the remaining files/streams directly into the
  consumer; ``calcNumSpillsFinalMerge`` is the *number of streams* in it.

Worked example used throughout the paper: ``N=30, F=10`` ->
first pass merges 3, intermediate reads total 23, final merge has 10 streams,
4 merge passes in total.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass

__all__ = [
    "calc_num_spills_first_pass",
    "calc_num_spills_interm_merge",
    "calc_num_spills_final_merge",
    "num_merge_passes",
    "ste_floor",
    "ste_ceil",
    "ste_round",
    "MergePlan",
    "simulate_merge",
    "merge_plan",
]


# --------------------------------------------------------------------------
# straight-through rounding (shared by the batched model and calibration)
# --------------------------------------------------------------------------
#
# The spill/merge round counts (Eqs. 15, 20-26, 31-32 and the reduce-side
# Eqs. 46-53 neighborhood) are floor/ceil expressions.  ``jnp.floor`` /
# ``jnp.ceil`` have an exactly-zero derivative, so any gradient taken
# through the job model w.r.t. the knobs behind them (pSortMB, pSpillPerc,
# pSortFactor, selectivities, ...) silently dies there — calibration and
# gradient search would see flat objectives.  These helpers keep the
# FORWARD VALUES BIT-FOR-BIT IDENTICAL to jnp.floor/jnp.ceil/jnp.round
# while letting the cotangent pass through unchanged for finite inputs
# (the straight-through estimator: d/dx = 1; non-finite inputs get a zero
# tangent so an ``inf`` primal can never turn a finite cotangent into NaN).
#
# They are declared via ``jax.custom_jvp`` rather than the classic
# ``rounded + (x - stop_gradient(x))`` trick: the forward jaxpr then
# contains a ``custom_jvp_call`` wrapping the bare rounding primitive,
# which is how `repro.analysis`'s grad-blocker checker distinguishes
# *intentional* straight-through rounding from a stray ``jnp.floor`` that
# would silently zero a calibration gradient.

_STE_CACHE: dict = {}


def _ste_wrap(name: str):
    """Build (once) a custom-JVP straight-through version of jnp.<name>."""
    import jax
    import jax.numpy as jnp

    if name in _STE_CACHE:
        return _STE_CACHE[name]

    rounder = getattr(jnp, name)

    @jax.custom_jvp
    def ste(x):
        return rounder(x)

    @ste.defjvp
    def _ste_jvp(primals, tangents):
        (x,), (t,) = primals, tangents
        # straight-through: d/dx = 1 for finite x.  The double-where keeps a
        # non-finite primal from producing NaN tangents (0 * inf).
        safe_t = jnp.where(jnp.isfinite(x), t, 0.0)
        return rounder(x), safe_t

    ste.__name__ = f"ste_{name}"
    _STE_CACHE[name] = ste
    return ste


def ste_floor(x):
    """``jnp.floor(x)`` forward, identity gradient (straight-through)."""
    return _ste_wrap("floor")(x)


def ste_ceil(x):
    """``jnp.ceil(x)`` forward, identity gradient (straight-through)."""
    return _ste_wrap("ceil")(x)


def ste_round(x):
    """``jnp.round(x)`` forward, identity gradient (straight-through)."""
    return _ste_wrap("round")(x)


def calc_num_spills_first_pass(n: int, f: int) -> int:
    """Eq. 20 — number of spills merged by the first merge pass."""
    if n <= f:
        return n
    if (n - 1) % (f - 1) == 0:
        return f
    return (n - 1) % (f - 1) + 1


def calc_num_spills_interm_merge(n: int, f: int) -> int:
    """Eq. 21 — spill-equivalents read during first + intermediate passes.

    Closed form valid for ``n <= f**2`` (asserted); use :func:`simulate_merge`
    beyond that, as the paper prescribes.
    """
    if n <= f:
        return 0
    assert n <= f * f, f"closed form requires N <= F^2 (got N={n}, F={f})"
    p = calc_num_spills_first_pass(n, f)
    return p + ((n - p) // f) * f


def calc_num_spills_final_merge(n: int, f: int) -> int:
    """Eq. 22 — number of streams merged by the final merge pass."""
    if n <= f:
        return n
    assert n <= f * f, f"closed form requires N <= F^2 (got N={n}, F={f})"
    p = calc_num_spills_first_pass(n, f)
    s = calc_num_spills_interm_merge(n, f)
    return 1 + (n - p) // f + (n - s)


def num_merge_passes(n: int, f: int) -> int:
    """Eq. 25 — total number of merge passes (incl. first and final)."""
    if n <= 1:
        return 0
    if n <= f:
        return 1
    assert n <= f * f, f"closed form requires N <= F^2 (got N={n}, F={f})"
    p = calc_num_spills_first_pass(n, f)
    return 2 + (n - p) // f


@dataclass(frozen=True)
class MergePlan:
    """Full accounting of a multi-pass merge of ``n`` unit-weight spills."""

    n: int
    f: int
    first_pass: int          # files merged in the first pass
    interm_reads: float      # spill-equivalents read before the final pass
    final_merge_width: int   # streams in the final merge
    passes: int              # total merge passes (incl. first and final)


def simulate_merge(n: int, f: int) -> MergePlan:
    """Exact simulation of Hadoop's merge loop for arbitrary ``n``.

    Replicates ``org.apache.hadoop.mapred.Merger`` semantics: the first pass
    merges ``calc_num_spills_first_pass(n, f)`` of the smallest files; every
    subsequent pass merges the ``f`` smallest remaining files, until at most
    ``f`` remain, which form the final merge.  File sizes are tracked in
    spill-equivalents (original spills have weight 1; merged files carry the
    summed weight) so re-merges of merged files — which occur only when
    ``n > f**2`` — are charged correctly.

    For ``n <= f**2`` this reproduces the paper's closed forms exactly
    (property-tested in ``tests/test_merge_math.py``).
    """
    if n <= 1:
        return MergePlan(n, f, 0, 0.0, n, 0)
    if n <= f:
        return MergePlan(n, f, n, 0.0, n, 1)

    heap: list[float] = [1.0] * n
    heapq.heapify(heap)
    interm_reads = 0.0
    passes = 0

    # First pass: merge P smallest files.
    p = calc_num_spills_first_pass(n, f)
    merged = sum(heapq.heappop(heap) for _ in range(p))
    interm_reads += merged
    heapq.heappush(heap, merged)
    passes += 1

    # Intermediate passes: merge F smallest until <= F files remain.
    while len(heap) > f:
        merged = sum(heapq.heappop(heap) for _ in range(f))
        interm_reads += merged
        heapq.heappush(heap, merged)
        passes += 1

    # Final merge of whatever remains.
    final_width = len(heap)
    passes += 1
    return MergePlan(n, f, p, interm_reads, final_width, passes)


def merge_plan(n: int, f: int) -> MergePlan:
    """Closed forms when valid (``n <= f**2``), exact simulation otherwise."""
    if n <= f * f:
        return MergePlan(
            n,
            f,
            calc_num_spills_first_pass(n, f) if n > f else (n if n > 1 else 0),
            float(calc_num_spills_interm_merge(n, f)),
            calc_num_spills_final_merge(n, f) if n > 1 else n,
            num_merge_passes(n, f),
        )
    return simulate_merge(n, f)
