"""Faithful implementation of the paper's Hadoop performance models.

The typed public surface over these models — :class:`repro.spec.JobSpec`
(Tables 1-3 as one value), :class:`repro.spec.CostReport` (per-phase costs
with Eq numbers) and the :mod:`repro.api` facade — lives one layer up;
everything here remains the flat, dict-keyed ground truth it adapts.
"""

from .merge_math import (
    MergePlan,
    calc_num_spills_final_merge,
    calc_num_spills_first_pass,
    calc_num_spills_interm_merge,
    merge_plan,
    num_merge_passes,
    simulate_merge,
)
from .model import CONFIG_KEYS, job_model_jnp, pack_config
from .params import (
    CostFactors,
    HadoopParams,
    MiB,
    ProfileStats,
    apply_initializations,
)
from .ref import (
    JobModel,
    MapTaskModel,
    ReduceTaskModel,
    job_model,
    map_task_model,
    network_model,
    reduce_task_model,
)
from .simulator import SimConfig, SimResult, TaskRecord, simulate_job

__all__ = [
    "MiB",
    "HadoopParams",
    "ProfileStats",
    "CostFactors",
    "apply_initializations",
    "MergePlan",
    "calc_num_spills_first_pass",
    "calc_num_spills_interm_merge",
    "calc_num_spills_final_merge",
    "num_merge_passes",
    "merge_plan",
    "simulate_merge",
    "MapTaskModel",
    "ReduceTaskModel",
    "JobModel",
    "map_task_model",
    "reduce_task_model",
    "network_model",
    "job_model",
    "pack_config",
    "job_model_jnp",
    "CONFIG_KEYS",
    "SimConfig",
    "SimResult",
    "TaskRecord",
    "simulate_job",
]
