"""Model parameters for the Hadoop performance models (paper §1, Tables 1-3).

Three parameter groups, exactly as the paper defines them:

* :class:`HadoopParams`   — Table 1: Hadoop-defined configuration parameters.
* :class:`ProfileStats`   — Table 2: data / UDF profile statistics.
* :class:`CostFactors`    — Table 3: I/O, CPU and network cost factors.

Cost-factor units follow the paper: I/O costs and (de)compression CPU costs are
seconds **per byte**; the remaining CPU costs are seconds **per key-value
pair**; the network cost is seconds per byte transferred.  All model outputs
are therefore in seconds.

The paper's "Initializations" block (the ``If (pUseCombine == FALSE) ...``
rules after Eq. 1) is implemented by :func:`apply_initializations`, which
returns *normalized* copies of the stats / cost factors so that every
downstream formula can be written without conditionals, exactly as the paper
intends.

:class:`repro.spec.JobSpec` bundles the three dataclasses into one frozen
pytree-registered value, and :func:`repro.spec.hadoop_space` exposes each
field as a declarative :class:`~repro.spec.Axis` (kind, bounds, unit,
source table) — use those for anything that routes flat float overrides
back onto these types.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

__all__ = [
    "MiB",
    "HadoopParams",
    "ProfileStats",
    "CostFactors",
    "apply_initializations",
]

MiB = 1 << 20  # 2**20 bytes; the paper's io.sort.mb unit


@dataclass(frozen=True)
class HadoopParams:
    """Table 1 — Hadoop parameter variables (defaults from the paper)."""

    # --- system ---
    pNumNodes: int = 1
    pTaskMem: float = 200.0 * MiB        # mapred.child.java.opts (-Xmx200m)
    pMaxMapsPerNode: int = 2             # mapred.tasktracker.map.tasks.max
    pMaxRedPerNode: int = 2              # mapred.tasktracker.reduce.tasks.max
    # --- job ---
    pNumMappers: int = 1                 # mapred.map.tasks
    pSortMB: float = 100.0               # io.sort.mb (MB)
    pSpillPerc: float = 0.8              # io.sort.spill.percent
    pSortRecPerc: float = 0.05           # io.sort.record.percent
    pSortFactor: int = 10                # io.sort.factor
    pNumSpillsForComb: int = 3           # min.num.spills.for.combine
    pNumReducers: int = 1                # mapred.reduce.tasks
    pInMemMergeThr: int = 1000           # mapred.inmem.merge.threshold
    pShuffleInBufPerc: float = 0.7       # mapred.job.shuffle.input.buffer.percent
    pShuffleMergePerc: float = 0.66      # mapred.job.shuffle.merge.percent
    pReducerInBufPerc: float = 0.0       # mapred.job.reduce.input.buffer.percent
    pUseCombine: bool = False            # mapred.combine.class set?
    pIsIntermCompressed: bool = False    # mapred.compress.map.output
    pIsOutCompressed: bool = False       # mapred.output.compress
    pReduceSlowstart: float = 0.05       # mapred.reduce.slowstart.completed.maps
    # --- input ---
    pIsInCompressed: bool = False        # input compressed?
    pSplitSize: float = 128.0 * MiB      # input split size (bytes)

    def replace(self, **kw) -> "HadoopParams":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ProfileStats:
    """Table 2 — profile statistics of the data and the user-defined functions."""

    sInputPairWidth: float = 100.0       # bytes per input K-V pair
    sMapSizeSel: float = 1.0             # map selectivity (size)
    sMapPairsSel: float = 1.0            # map selectivity (pairs)
    sReduceSizeSel: float = 1.0          # reduce selectivity (size)
    sReducePairsSel: float = 1.0         # reduce selectivity (pairs)
    sCombineSizeSel: float = 1.0         # combine selectivity (size)
    sCombinePairsSel: float = 1.0        # combine selectivity (pairs)
    sInputCompressRatio: float = 1.0     # compressed/uncompressed for input
    sIntermCompressRatio: float = 1.0    # compressed/uncompressed for map output
    sOutCompressRatio: float = 1.0       # compressed/uncompressed for job output

    def replace(self, **kw) -> "ProfileStats":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class CostFactors:
    """Table 3 — I/O / CPU / network cost factors.

    Defaults are representative of 2011-era commodity hardware (roughly the
    cluster the paper's Starfish experiments used): ~66 MB/s HDFS streams,
    ~80 MB/s local disk, ~1 Gbit/s network, and per-pair CPU costs of a few
    hundred nanoseconds.  They only set a realistic *scale*; every benchmark
    and the MapReduce-on-JAX harness re-fits them from measurements.
    """

    cHdfsReadCost: float = 1.5e-8        # s/byte  (~66 MB/s)
    cHdfsWriteCost: float = 1.5e-8       # s/byte
    cLocalIOCost: float = 1.2e-8         # s/byte  (~80 MB/s)
    cNetworkCost: float = 8.0e-9         # s/byte  (~1 Gb/s)
    cMapCPUCost: float = 5.0e-7          # s/pair
    cReduceCPUCost: float = 5.0e-7       # s/pair
    cCombineCPUCost: float = 4.0e-7      # s/pair
    cPartitionCPUCost: float = 1.0e-7    # s/pair
    cSerdeCPUCost: float = 1.5e-7        # s/pair
    cSortCPUCost: float = 1.0e-7         # s/pair (per comparison level)
    cMergeCPUCost: float = 1.0e-7        # s/pair
    cInUncomprCPUCost: float = 3.0e-9    # s/byte
    cIntermUncomprCPUCost: float = 3.0e-9  # s/byte
    cIntermComprCPUCost: float = 6.0e-9  # s/byte
    cOutComprCPUCost: float = 6.0e-9     # s/byte

    def replace(self, **kw) -> "CostFactors":
        return dataclasses.replace(self, **kw)


def apply_initializations(
    p: HadoopParams, s: ProfileStats, c: CostFactors
) -> tuple[ProfileStats, CostFactors]:
    """The paper's Initializations block (after Eq. 1).

    Returns normalized copies of ``(stats, costs)`` so the formulas need no
    conditionals:

    * no combiner       -> combine selectivities = 1, cCombineCPUCost = 0
    * input uncompressed -> sInputCompressRatio = 1, cInUncomprCPUCost = 0
    * interm uncompressed -> sIntermCompressRatio = 1,
      cIntermUncomprCPUCost = 0 (and, by the same logic, the compression
      cost cIntermComprCPUCost = 0 — the paper zeroes the decompression
      factor explicitly; compression of intermediates cannot occur either)
    * output uncompressed -> sOutCompressRatio = 1, cOutComprCPUCost = 0
    """
    s_kw: dict = {}
    c_kw: dict = {}
    if not p.pUseCombine:
        s_kw.update(sCombineSizeSel=1.0, sCombinePairsSel=1.0)
        c_kw.update(cCombineCPUCost=0.0)
    if not p.pIsInCompressed:
        s_kw.update(sInputCompressRatio=1.0)
        c_kw.update(cInUncomprCPUCost=0.0)
    if not p.pIsIntermCompressed:
        s_kw.update(sIntermCompressRatio=1.0)
        c_kw.update(cIntermUncomprCPUCost=0.0, cIntermComprCPUCost=0.0)
    if not p.pIsOutCompressed:
        s_kw.update(sOutCompressRatio=1.0)
        c_kw.update(cOutComprCPUCost=0.0)
    return s.replace(**s_kw), c.replace(**c_kw)
