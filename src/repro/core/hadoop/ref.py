"""Pure-Python reference implementation of the Hadoop performance models.

Direct, auditable transcription of the paper's equations (Eqs. 2-98) using
plain floats, ``math.floor/ceil`` and ``if`` statements, in paper order.  This
is the oracle that the vectorized JAX model (:mod:`repro.core.hadoop.model`)
is property-tested against, mirroring the kernels' ``ref.py`` pattern.

Documented deviations from the paper text (applied identically in both
implementations so they stay equivalent):

* Eq. 19 (sort CPU): ``log2(spillBufferPairs / pNumReducers)`` is clamped at
  ``>= 0`` — a buffer with fewer pairs than partitions would otherwise
  produce a *negative* sorting cost.
* Eq. 31/32 are charged only when ``numSpills > 1`` (§2.3: "The merge phase
  will occur only if more than one spill file is created").
* Eq. 80 (merge CPU of the reduce sort phase): the paper multiplies
  ``totalMergingSize`` (bytes) by ``cMergeCPUCost`` (a *per-pair* factor,
  Table 3); we use ``totalMergingPairs``, the pair counts the paper itself
  computes in Eqs. 71/76, which restores dimensional consistency.
* Eq. 82 references ``segmentComprPairs`` which is never defined; the only
  matching quantity is ``segmentPairs`` (Eq. 37) and is used here.
* Step-3 ratios (Eqs. 75-76) guard the 0/0 case (no files at all) to 0.
* Eq. 67 is implemented literally: when ``numFilesOnDisk < pSortFactor`` one
  file-from-memory is accounted even if zero segments were evicted (its size
  is then 0).  This matches the paper text; see tests for the edge case.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from .merge_math import merge_plan
from .params import MiB, CostFactors, HadoopParams, ProfileStats, apply_initializations

__all__ = [
    "MapTaskModel",
    "ReduceTaskModel",
    "JobModel",
    "map_task_model",
    "reduce_task_model",
    "network_model",
    "job_model",
]


# --------------------------------------------------------------------------
# Result containers: every paper intermediate is a field, for testability.
# --------------------------------------------------------------------------


@dataclass
class MapTaskModel:
    # Read/Map (Eqs. 2-7)
    inputMapSize: float = 0.0
    inputMapPairs: float = 0.0
    ioReadCost: float = 0.0
    cpuReadCost: float = 0.0
    ioMapWriteCost: float = 0.0
    cpuMapWriteCost: float = 0.0
    # Collect/Spill (Eqs. 8-19)
    outMapSize: float = 0.0
    outMapPairs: float = 0.0
    outPairWidth: float = 0.0
    maxSerPairs: float = 0.0
    maxAccPairs: float = 0.0
    spillBufferPairs: float = 0.0
    spillBufferSize: float = 0.0
    numSpills: int = 0
    spillFilePairs: float = 0.0
    spillFileSize: float = 0.0
    ioSpillCost: float = 0.0
    cpuSpillCost: float = 0.0
    # Merge (Eqs. 20-32)
    numSpillsFirstPass: int = 0
    numSpillsIntermMerge: float = 0.0
    numMergePasses: int = 0
    numSpillsFinalMerge: int = 0
    numRecSpilled: float = 0.0
    useCombInMerge: bool = False
    intermDataSize: float = 0.0
    intermDataPairs: float = 0.0
    ioMergeCost: float = 0.0
    cpuMergeCost: float = 0.0
    # Totals (Eqs. 33-34)
    ioCost: float = 0.0
    cpuCost: float = 0.0


@dataclass
class ReduceTaskModel:
    # Shuffle (Eqs. 35-61)
    segmentComprSize: float = 0.0
    segmentUncomprSize: float = 0.0
    segmentPairs: float = 0.0
    totalShuffleSize: float = 0.0
    totalShufflePairs: float = 0.0
    shuffleBufferSize: float = 0.0
    mergeSizeThr: float = 0.0
    inMemCase: bool = True  # Case 1 (segment fits in-memory pipeline)?
    numSegInShuffleFile: float = 0.0
    shuffleFileSize: float = 0.0
    shuffleFilePairs: float = 0.0
    numShuffleFiles: float = 0.0
    numSegmentsInMem: float = 0.0
    numShuffleMerges: float = 0.0
    numMergShufFiles: float = 0.0
    mergShufFileSize: float = 0.0
    mergShufFilePairs: float = 0.0
    numUnmergShufFiles: float = 0.0
    unmergShufFileSize: float = 0.0
    unmergShufFilePairs: float = 0.0
    ioShuffleCost: float = 0.0
    cpuShuffleCost: float = 0.0
    # Sort/Merge (Eqs. 62-80)
    maxSegmentBuffer: float = 0.0
    currSegmentBuffer: float = 0.0
    numSegmentsEvicted: float = 0.0
    numSegmentsRemainMem: float = 0.0
    numFilesOnDisk: float = 0.0
    numFilesFromMem: float = 0.0
    filesFromMemSize: float = 0.0
    filesFromMemPairs: float = 0.0
    step1MergingSize: float = 0.0
    step1MergingPairs: float = 0.0
    filesToMergeStep2: float = 0.0
    step2MergingSize: float = 0.0
    step2MergingPairs: float = 0.0
    filesRemainFromStep2: float = 0.0
    filesToMergeStep3: float = 0.0
    step3MergingSize: float = 0.0
    step3MergingPairs: float = 0.0
    filesRemainFromStep3: float = 0.0
    totalMergingSize: float = 0.0
    totalMergingPairs: float = 0.0
    ioSortCost: float = 0.0
    cpuSortCost: float = 0.0
    # Reduce/Write (Eqs. 81-87)
    inReduceSize: float = 0.0
    inReducePairs: float = 0.0
    outReduceSize: float = 0.0
    outReducePairs: float = 0.0
    inRedDiskSize: float = 0.0
    ioWriteCost: float = 0.0
    cpuWriteCost: float = 0.0
    # Totals (Eqs. 88-89)
    ioCost: float = 0.0
    cpuCost: float = 0.0


@dataclass
class JobModel:
    map: MapTaskModel = field(default_factory=MapTaskModel)
    reduce: ReduceTaskModel = field(default_factory=ReduceTaskModel)
    netTransferSize: float = 0.0
    netCost: float = 0.0           # Eq. 91
    ioAllMaps: float = 0.0         # Eq. 92
    cpuAllMaps: float = 0.0        # Eq. 93
    ioAllReducers: float = 0.0     # Eq. 94
    cpuAllReducers: float = 0.0    # Eq. 95
    ioJobCost: float = 0.0         # Eq. 96
    cpuJobCost: float = 0.0        # Eq. 97
    totalCost: float = 0.0         # Eq. 98


# --------------------------------------------------------------------------
# §2 — Map task phases
# --------------------------------------------------------------------------


def map_task_model(
    p: HadoopParams, s: ProfileStats, c: CostFactors, *, normalized: bool = False
) -> MapTaskModel:
    """Model of a single map task (paper §2)."""
    if not normalized:
        s, c = apply_initializations(p, s, c)
    m = MapTaskModel()

    # --- §2.1 Read + Map (Eqs. 2-4) ---
    m.inputMapSize = p.pSplitSize / s.sInputCompressRatio          # Eq. 2
    m.inputMapPairs = m.inputMapSize / s.sInputPairWidth           # Eq. 3
    m.ioReadCost = p.pSplitSize * c.cHdfsReadCost
    m.cpuReadCost = (
        p.pSplitSize * c.cInUncomprCPUCost
        + m.inputMapPairs * c.cMapCPUCost                          # Eq. 4
    )

    # --- map output (Eqs. 5, 8-10) ---
    m.outMapSize = m.inputMapSize * s.sMapSizeSel                  # Eq. 5/8
    m.outMapPairs = m.inputMapPairs * s.sMapPairsSel               # Eq. 9
    m.outPairWidth = m.outMapSize / m.outMapPairs                  # Eq. 10

    if p.pNumReducers == 0:
        # Map-only job: write map output straight to HDFS (Eqs. 6-7).
        m.ioMapWriteCost = m.outMapSize * s.sOutCompressRatio * c.cHdfsWriteCost
        m.cpuMapWriteCost = m.outMapSize * c.cOutComprCPUCost
        m.ioCost = m.ioReadCost + m.ioMapWriteCost                 # Eq. 33
        m.cpuCost = m.cpuReadCost + m.cpuMapWriteCost              # Eq. 34
        # Map-only intermediate data == final map output.
        m.intermDataSize = m.outMapSize
        m.intermDataPairs = m.outMapPairs
        return m

    # --- §2.2 Collect + Spill (Eqs. 11-19) ---
    m.maxSerPairs = math.floor(
        p.pSortMB * MiB * (1.0 - p.pSortRecPerc) * p.pSpillPerc / m.outPairWidth
    )                                                              # Eq. 11
    m.maxAccPairs = math.floor(
        p.pSortMB * MiB * p.pSortRecPerc * p.pSpillPerc / 16.0
    )                                                              # Eq. 12
    m.spillBufferPairs = max(
        1.0, min(m.maxSerPairs, m.maxAccPairs, m.outMapPairs)
    )                                                              # Eq. 13
    m.spillBufferSize = m.spillBufferPairs * m.outPairWidth        # Eq. 14
    m.numSpills = math.ceil(m.outMapPairs / m.spillBufferPairs)    # Eq. 15
    m.spillFilePairs = m.spillBufferPairs * s.sCombinePairsSel     # Eq. 16
    m.spillFileSize = (
        m.spillBufferSize * s.sCombineSizeSel * s.sIntermCompressRatio
    )                                                              # Eq. 17

    m.ioSpillCost = m.numSpills * m.spillFileSize * c.cLocalIOCost  # Eq. 18
    sort_depth = max(0.0, math.log2(m.spillBufferPairs / p.pNumReducers))
    m.cpuSpillCost = m.numSpills * (                               # Eq. 19
        m.spillBufferPairs * c.cPartitionCPUCost
        + m.spillBufferPairs * c.cSerdeCPUCost
        + m.spillBufferPairs * sort_depth * c.cSortCPUCost
        + m.spillBufferPairs * c.cCombineCPUCost
        + m.spillBufferSize * s.sCombineSizeSel * c.cIntermComprCPUCost
    )

    # --- §2.3 Merge (Eqs. 20-32) ---
    plan = merge_plan(m.numSpills, p.pSortFactor)
    m.numSpillsFirstPass = plan.first_pass                         # Eq. 23
    m.numSpillsIntermMerge = plan.interm_reads                     # Eq. 24
    m.numMergePasses = plan.passes                                 # Eq. 25
    m.numSpillsFinalMerge = plan.final_merge_width                 # Eq. 26

    m.numRecSpilled = m.spillFilePairs * (                         # Eq. 27
        m.numSpills + m.numSpillsIntermMerge + m.numSpills * s.sCombinePairsSel
    )

    m.useCombInMerge = (                                           # Eq. 28
        m.numSpills > 1
        and p.pUseCombine
        and m.numSpillsFinalMerge >= p.pNumSpillsForComb
    )
    comb_size = s.sCombineSizeSel if m.useCombInMerge else 1.0
    comb_pairs = s.sCombinePairsSel if m.useCombInMerge else 1.0
    m.intermDataSize = m.numSpills * m.spillFileSize * comb_size   # Eq. 29
    m.intermDataPairs = m.numSpills * m.spillFilePairs * comb_pairs  # Eq. 30

    if m.numSpills > 1:
        m.ioMergeCost = (                                          # Eq. 31
            2.0 * m.numSpillsIntermMerge * m.spillFileSize * c.cLocalIOCost
            + m.numSpills * m.spillFileSize * c.cLocalIOCost
            + m.intermDataSize * c.cLocalIOCost
        )
        m.cpuMergeCost = (                                         # Eq. 32
            m.numSpillsIntermMerge
            * (
                m.spillFileSize * c.cIntermUncomprCPUCost
                + m.spillFilePairs * c.cMergeCPUCost
                + (m.spillFileSize / s.sIntermCompressRatio)
                * c.cIntermComprCPUCost
            )
            + m.numSpills
            * (
                m.spillFileSize * c.cIntermUncomprCPUCost
                + m.spillFilePairs * c.cMergeCPUCost
                + m.spillFilePairs * c.cCombineCPUCost
            )
            + (m.intermDataSize / s.sIntermCompressRatio) * c.cIntermComprCPUCost
        )

    m.ioCost = m.ioReadCost + m.ioSpillCost + m.ioMergeCost        # Eq. 33
    m.cpuCost = m.cpuReadCost + m.cpuSpillCost + m.cpuMergeCost    # Eq. 34
    return m


# --------------------------------------------------------------------------
# §3 — Reduce task phases
# --------------------------------------------------------------------------


def reduce_task_model(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    m: MapTaskModel,
    *,
    normalized: bool = False,
) -> ReduceTaskModel:
    """Model of a single reduce task (paper §3), given the map-task model."""
    if not normalized:
        s, c = apply_initializations(p, s, c)
    r = ReduceTaskModel()
    F = p.pSortFactor

    # --- §3.1 Shuffle (Eqs. 35-41) ---
    r.segmentComprSize = m.intermDataSize / p.pNumReducers         # Eq. 35
    r.segmentUncomprSize = r.segmentComprSize / s.sIntermCompressRatio  # Eq. 36
    r.segmentPairs = m.intermDataPairs / p.pNumReducers            # Eq. 37
    r.totalShuffleSize = p.pNumMappers * r.segmentComprSize        # Eq. 38
    r.totalShufflePairs = p.pNumMappers * r.segmentPairs           # Eq. 39
    r.shuffleBufferSize = p.pShuffleInBufPerc * p.pTaskMem         # Eq. 40
    r.mergeSizeThr = p.pShuffleMergePerc * r.shuffleBufferSize     # Eq. 41

    r.inMemCase = r.segmentUncomprSize < 0.25 * r.shuffleBufferSize
    if r.inMemCase:
        # Case 1 (Eqs. 42-47)
        nseg = r.mergeSizeThr / max(r.segmentUncomprSize, 1e-30)   # Eq. 42
        if math.ceil(nseg) * r.segmentUncomprSize <= r.shuffleBufferSize:
            nseg = float(math.ceil(nseg))                          # Eq. 43
        else:
            nseg = float(math.floor(nseg))
        nseg = max(1.0, min(nseg, float(p.pInMemMergeThr)))
        r.numSegInShuffleFile = nseg
        r.shuffleFileSize = (
            nseg * r.segmentComprSize * s.sCombineSizeSel
        )                                                          # Eq. 44
        r.shuffleFilePairs = nseg * r.segmentPairs * s.sCombinePairsSel  # Eq. 45
        r.numShuffleFiles = float(p.pNumMappers // int(nseg))      # Eq. 46
        r.numSegmentsInMem = float(p.pNumMappers % int(nseg))      # Eq. 47
    else:
        # Case 2 (Eqs. 48-52)
        r.numSegInShuffleFile = 1.0
        r.shuffleFileSize = r.segmentComprSize
        r.shuffleFilePairs = r.segmentPairs
        r.numShuffleFiles = float(p.pNumMappers)
        r.numSegmentsInMem = 0.0

    # On-disk merges during shuffle (Eq. 53).
    if r.numShuffleFiles < 2 * F - 1:
        r.numShuffleMerges = 0.0
    else:
        r.numShuffleMerges = float(
            int((r.numShuffleFiles - 2 * F + 1) // F) + 1
        )
    r.numMergShufFiles = r.numShuffleMerges                        # Eq. 54
    r.mergShufFileSize = F * r.shuffleFileSize                     # Eq. 55
    r.mergShufFilePairs = F * r.shuffleFilePairs                   # Eq. 56
    r.numUnmergShufFiles = r.numShuffleFiles - F * r.numShuffleMerges  # Eq. 57
    r.unmergShufFileSize = r.shuffleFileSize                       # Eq. 58
    r.unmergShufFilePairs = r.shuffleFilePairs                     # Eq. 59

    r.ioShuffleCost = (                                            # Eq. 60
        r.numShuffleFiles * r.shuffleFileSize * c.cLocalIOCost
        + r.numMergShufFiles * r.mergShufFileSize * 2.0 * c.cLocalIOCost
    )
    in_mem_term = (                                                # Eq. 61
        r.totalShuffleSize * c.cIntermUncomprCPUCost
        + r.numShuffleFiles * r.shuffleFilePairs * c.cMergeCPUCost
        + r.numShuffleFiles * r.shuffleFilePairs * c.cCombineCPUCost
        + r.numShuffleFiles
        * (r.shuffleFileSize / s.sIntermCompressRatio)
        * c.cIntermComprCPUCost
    )
    r.cpuShuffleCost = (
        (in_mem_term if r.inMemCase else 0.0)
        + r.numMergShufFiles * r.mergShufFileSize * c.cIntermUncomprCPUCost
        + r.numMergShufFiles * r.mergShufFilePairs * c.cMergeCPUCost
        + r.numMergShufFiles
        * (r.mergShufFileSize / s.sIntermCompressRatio)
        * c.cIntermComprCPUCost
    )

    # --- §3.2 Sort/Merge: Step 1 (Eqs. 62-67) ---
    r.maxSegmentBuffer = p.pReducerInBufPerc * p.pTaskMem          # Eq. 62
    r.currSegmentBuffer = r.numSegmentsInMem * r.segmentUncomprSize  # Eq. 63
    if r.currSegmentBuffer > r.maxSegmentBuffer:
        r.numSegmentsEvicted = math.ceil(                          # Eq. 64
            (r.currSegmentBuffer - r.maxSegmentBuffer)
            / max(r.segmentUncomprSize, 1e-30)
        )
    else:
        r.numSegmentsEvicted = 0.0
    r.numSegmentsRemainMem = r.numSegmentsInMem - r.numSegmentsEvicted  # Eq. 65
    r.numFilesOnDisk = r.numMergShufFiles + r.numUnmergShufFiles   # Eq. 66

    if r.numFilesOnDisk < F:                                       # Eq. 67
        r.numFilesFromMem = 1.0
        r.filesFromMemSize = r.numSegmentsEvicted * r.segmentComprSize
        r.filesFromMemPairs = r.numSegmentsEvicted * r.segmentPairs
        r.step1MergingSize = r.filesFromMemSize
        r.step1MergingPairs = r.filesFromMemPairs
    else:
        r.numFilesFromMem = r.numSegmentsEvicted
        r.filesFromMemSize = r.segmentComprSize
        r.filesFromMemPairs = r.segmentPairs
        r.step1MergingSize = 0.0
        r.step1MergingPairs = 0.0

    r.filesToMergeStep2 = r.numFilesOnDisk + r.numFilesFromMem     # Eq. 68

    # --- Step 2 (Eqs. 69-72): only if files exist on disk ---
    if r.numFilesOnDisk > 0:
        plan2 = merge_plan(int(r.filesToMergeStep2), F)
        interm2 = plan2.interm_reads                               # Eq. 69
        ratio2 = interm2 / r.filesToMergeStep2
        pool_size = (
            r.numMergShufFiles * r.mergShufFileSize
            + r.numUnmergShufFiles * r.unmergShufFileSize
            + r.numFilesFromMem * r.filesFromMemSize
        )
        pool_pairs = (
            r.numMergShufFiles * r.mergShufFilePairs
            + r.numUnmergShufFiles * r.unmergShufFilePairs
            + r.numFilesFromMem * r.filesFromMemPairs
        )
        r.step2MergingSize = ratio2 * pool_size                    # Eq. 70
        r.step2MergingPairs = ratio2 * pool_pairs                  # Eq. 71
        r.filesRemainFromStep2 = float(plan2.final_merge_width)    # Eq. 72
    else:
        r.filesRemainFromStep2 = r.filesToMergeStep2

    # --- Step 3 (Eqs. 73-77) ---
    r.filesToMergeStep3 = r.filesRemainFromStep2 + r.numSegmentsRemainMem  # Eq. 73
    if r.filesToMergeStep3 > 0:
        plan3 = merge_plan(int(r.filesToMergeStep3), F)
        interm3 = plan3.interm_reads                               # Eq. 74
        ratio3 = interm3 / r.filesToMergeStep3
        r.step3MergingSize = ratio3 * r.totalShuffleSize           # Eq. 75
        r.step3MergingPairs = ratio3 * r.totalShufflePairs         # Eq. 76
        r.filesRemainFromStep3 = float(plan3.final_merge_width)    # Eq. 77

    r.totalMergingSize = (                                         # Eq. 78
        r.step1MergingSize + r.step2MergingSize + r.step3MergingSize
    )
    r.totalMergingPairs = (
        r.step1MergingPairs + r.step2MergingPairs + r.step3MergingPairs
    )

    r.ioSortCost = r.totalMergingSize * c.cLocalIOCost             # Eq. 79
    r.cpuSortCost = (                                              # Eq. 80
        r.totalMergingPairs * c.cMergeCPUCost
        + (r.totalMergingSize / s.sIntermCompressRatio) * c.cIntermComprCPUCost
        + (r.step2MergingSize + r.step3MergingSize) * c.cIntermUncomprCPUCost
    )

    # --- §3.3 Reduce + Write (Eqs. 81-87) ---
    r.inReduceSize = (                                             # Eq. 81
        r.numShuffleFiles * r.shuffleFileSize / s.sIntermCompressRatio
        + r.numSegmentsInMem * r.segmentComprSize / s.sIntermCompressRatio
    )
    r.inReducePairs = (                                            # Eq. 82
        r.numShuffleFiles * r.shuffleFilePairs
        + r.numSegmentsInMem * r.segmentPairs
    )
    r.outReduceSize = r.inReduceSize * s.sReduceSizeSel            # Eq. 83
    r.outReducePairs = r.inReducePairs * s.sReducePairsSel         # Eq. 84
    r.inRedDiskSize = (                                            # Eq. 85
        r.numMergShufFiles * r.mergShufFileSize
        + r.numUnmergShufFiles * r.unmergShufFileSize
        + r.numFilesFromMem * r.filesFromMemSize
    )
    r.ioWriteCost = (                                              # Eq. 86
        r.inRedDiskSize * c.cLocalIOCost
        + r.outReduceSize * s.sOutCompressRatio * c.cHdfsWriteCost
    )
    r.cpuWriteCost = (                                             # Eq. 87
        r.inReducePairs * c.cReduceCPUCost
        + r.inRedDiskSize * c.cIntermUncomprCPUCost
        + r.outReduceSize * c.cOutComprCPUCost
    )

    r.ioCost = r.ioShuffleCost + r.ioSortCost + r.ioWriteCost      # Eq. 88
    r.cpuCost = r.cpuShuffleCost + r.cpuSortCost + r.cpuWriteCost  # Eq. 89
    return r


# --------------------------------------------------------------------------
# §4 + §5 — Network and whole-job models
# --------------------------------------------------------------------------


def network_model(
    p: HadoopParams, c: CostFactors, finalOutMapSize: float
) -> tuple[float, float]:
    """Eqs. 90-91 — shuffle network transfer size and cost."""
    frac = (p.pNumNodes - 1) / p.pNumNodes if p.pNumNodes > 0 else 0.0
    size = finalOutMapSize * p.pNumMappers * frac                  # Eq. 90
    return size, size * c.cNetworkCost                             # Eq. 91


def job_model(p: HadoopParams, s: ProfileStats, c: CostFactors) -> JobModel:
    """Analytic whole-job model (paper §5, Eqs. 92-98)."""
    s, c = apply_initializations(p, s, c)
    j = JobModel()
    j.map = map_task_model(p, s, c, normalized=True)

    map_slots = p.pNumNodes * p.pMaxMapsPerNode
    j.ioAllMaps = p.pNumMappers * j.map.ioCost / map_slots         # Eq. 92
    j.cpuAllMaps = p.pNumMappers * j.map.cpuCost / map_slots       # Eq. 93

    if p.pNumReducers > 0:
        j.reduce = reduce_task_model(p, s, c, j.map, normalized=True)
        red_slots = p.pNumNodes * p.pMaxRedPerNode
        j.ioAllReducers = p.pNumReducers * j.reduce.ioCost / red_slots   # Eq. 94
        j.cpuAllReducers = p.pNumReducers * j.reduce.cpuCost / red_slots  # Eq. 95
        j.netTransferSize, j.netCost = network_model(p, c, j.map.intermDataSize)

    j.ioJobCost = j.ioAllMaps + j.ioAllReducers                    # Eq. 96
    j.cpuJobCost = j.cpuAllMaps + j.cpuAllReducers                 # Eq. 97
    j.totalCost = j.ioJobCost + j.cpuJobCost + j.netCost           # Eq. 98
    return j
