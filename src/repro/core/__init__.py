"""Core of the reproduction: the paper's analytical performance models.

* :mod:`repro.core.hadoop`   — faithful Hadoop MapReduce models (Eqs. 1-98)
* :mod:`repro.core.whatif`   — vectorized what-if engine (vmap over configs)
* :mod:`repro.core.tuner`    — configuration-space optimizers
* :mod:`repro.core.tpu_model` — the methodology adapted to TPU step costs
* :mod:`repro.core.roofline` — roofline-term extraction from dry-run artifacts
"""

from . import hadoop  # noqa: F401
