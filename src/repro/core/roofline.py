"""Roofline-term extraction from compiled dry-run artifacts.

The paper's Eq. 98 — ``Cost = IOCost + CPUCost + NETCost`` — transplanted to
TPU:

    compute term    = HLO_FLOPs      / (chips x peak_FLOP/s)
    memory term     = HLO_bytes      / (chips x HBM_bw)
    collective term = collective_B   / (chips x link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
NOT in cost_analysis: :func:`collective_bytes` parses the post-partitioning
HLO (``compiled.as_text()``), sums the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
weights them by the standard ring-transfer factors, and multiplies ops that
live inside ``while`` bodies (lax.scan over layer groups / microbatches) by
the loop trip count recovered from the loop-condition constant.

Hardware constants are TPU v5e per chip: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (as specified for this task).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = [
    "HW",
    "RooflineTerms",
    "collective_bytes",
    "roofline_terms",
    "model_flops",
]

# TPU v5e hardware constants (per chip).
HW = {
    "peak_flops": 197e12,       # bf16 FLOP/s
    "hbm_bw": 819e9,            # B/s
    "ici_bw": 50e9,             # B/s per link (approximation: per chip)
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# bytes-on-the-wire factor per collective kind (ring algorithms, large N),
# applied to the RESULT shape bytes.  reduce-scatter's result is 1/N of the
# reduced tensor while each device still moves ~the full input over the ring,
# so its factor is the replica-group size (parsed per instruction).
_WIRE_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": None,     # group-size dependent: result x N
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
}

_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(line: str) -> int:
    """Replica-group size of a collective (iota or explicit format)."""
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9\[\],{}\s]*?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|ragged-all-to-all|"
    r"collective-permute)(?:-start)?\("
)


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every dtype[shape] token in a result description."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    total_bytes: float = 0.0
    by_kind: dict = field(default_factory=dict)
    count: int = 0


_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*->.*\{\s*$")


def _computations(hlo: str) -> dict[str, list[str]]:
    """Split HLO text into computation name -> list of body lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = _HEADER_RE.match(line)
        if m:
            cur = m.group(1).lstrip("%")
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Recover a scan trip count from the loop condition's compare constant."""
    consts = []
    for ln in cond_lines:
        if "constant(" in ln and ("s32" in ln or "s64" in ln or "u32" in ln):
            for m in re.finditer(r"constant\((-?\d+)\)", ln):
                consts.append(int(m.group(1)))
    pos = [c for c in consts if c > 0]
    return max(pos) if pos else 1


class _Program:
    """Parsed post-optimization HLO: computations + loop-trip multiplicity."""

    def __init__(self, hlo: str):
        self.comps = _computations(hlo)
        self.body_trips: dict[str, int] = {}
        while_re = re.compile(
            r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)"
        )
        trip_re = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
        call_re = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w\.\-]+)")
        self.callers: dict[str, list[str]] = {c: [] for c in self.comps}
        self.fused: set[str] = set()
        fusion_re = re.compile(r"fusion\(.*?calls=%?([\w\.\-]+)")
        for cname, lines in self.comps.items():
            for ln in lines:
                m = while_re.search(ln)
                if m:
                    cond, body = m.group(1), m.group(2)
                    tm = trip_re.search(ln)
                    if tm:
                        # XLA annotates analyzed loops explicitly — use it.
                        self.body_trips[body] = int(tm.group(1))
                    else:
                        # fall back: compare-constant in the loop condition.
                        self.body_trips[body] = _trip_count(
                            self.comps.get(cond, [])
                        )
                for fm in fusion_re.finditer(ln):
                    self.fused.add(fm.group(1))
                for cm in call_re.finditer(ln):
                    callee = cm.group(1)
                    if callee in self.callers:
                        self.callers[callee].append(cname)
        self._mult: dict[str, float] = {}

    def eff_mult(self, name: str, depth: int = 0) -> float:
        """Total times this computation executes (nested scan trip counts)."""
        if depth > 16:
            return 1.0
        if name in self._mult:
            return self._mult[name]
        own = self.body_trips.get(name, 1)
        ups = self.callers.get(name, [])
        parent = max((self.eff_mult(u, depth + 1) for u in ups), default=1.0)
        self._mult[name] = own * parent
        return self._mult[name]

    def symbols(self, lines: list[str]) -> dict[str, int]:
        """instruction name -> result bytes, for operand lookups."""
        table: dict[str, int] = {}
        for ln in lines:
            m = re.match(r"\s*(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s[a-z][\w\-]*\(", ln)
            if m:
                table[m.group(2)] = _shape_bytes(m.group(3))
        return table


def collective_bytes(hlo: str) -> CollectiveStats:
    """Per-device bytes moved by collectives in post-partitioning HLO,
    weighted by scan trip counts."""
    prog = _Program(hlo)
    stats = CollectiveStats()
    for cname, lines in prog.comps.items():
        mult = prog.eff_mult(cname)
        for ln in lines:
            m = _COLL_RE.search(ln)
            if not m:
                continue
            result_txt, kind = m.group(1), m.group(2)
            size = _shape_bytes(result_txt)
            if size == 0:
                size = _shape_bytes(ln.split("=")[0])
            factor = _WIRE_FACTOR.get(kind, 1.0)
            if factor is None:  # reduce-scatter: wire ~ full input
                factor = float(_group_size(ln))
            wire = size * factor * mult
            stats.total_bytes += wire
            stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + wire
            stats.count += 1
    return stats


_DOT_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+dot\(%?([\w\.\-]+),\s*%?([\w\.\-]+)\)"
    r".*?lhs_contracting_dims=\{([0-9,]*)\}"
)
_SHAPE_OF = re.compile(r"%?([\w\.\-]+)\s*=\s*[a-z0-9]+\[([0-9,]*)\]")
_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota",
}
_OP_RE = re.compile(r"=\s*.*?\s([a-z][\w\-]*)\(")


def hlo_totals(hlo: str) -> dict:
    """Trip-count-weighted PER-DEVICE totals parsed from post-opt HLO.

    The XLA:CPU ``cost_analysis()`` counts each while body ONCE, wildly
    undercounting lax.scan programs (layer stacks, grad accumulation).
    This parser multiplies per-computation contributions by the recovered
    loop trip counts.  The post-partitioning module is the per-device
    program, so every total here is per-chip.

    * ``dot_flops``: 2 * prod(result) * contracted-dims for every dot,
      weighted by trip count (fusion bodies inherit their caller's count).
    * ``out_bytes_w`` / ``out_bytes_1``: result bytes of every traffic-
      carrying instruction, trip-weighted and counted-once respectively.
      Their ratio is the loop-undercount correction applied to XLA's own
      ``bytes accessed`` (which models fusion operand slicing correctly but
      visits each while body once).  Operand bytes are deliberately NOT
      attributed here: a fusion reading a dynamic slice of a stacked
      loop-carry array would otherwise be charged the whole array per
      iteration.
    """
    prog = _Program(hlo)

    # global tables for operand lookups (instruction names are unique)
    dims_of: dict[str, list[int]] = {}
    decl_re = re.compile(
        r"%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*))\s"
    )
    for lines in prog.comps.values():
        for ln in lines:
            sm = _SHAPE_OF.search(ln)
            if sm:
                dims_of[sm.group(1)] = [
                    int(x) for x in sm.group(2).split(",") if x
                ] or [1]

    dot_flops = 0.0
    out_w = 0.0
    out_1 = 0.0
    for cname, lines in prog.comps.items():
        mult = prog.eff_mult(cname)
        in_fused = cname in prog.fused
        for ln in lines:
            dm = _DOT_RE.search(ln)
            if dm:
                out_dims = [int(x) for x in dm.group(2).split(",") if x] or [1]
                cdims = [int(x) for x in dm.group(5).split(",") if x]
                lhs_dims = dims_of.get(dm.group(3), [1])
                contracted = 1
                for c in cdims:
                    if c < len(lhs_dims):
                        contracted *= lhs_dims[c]
                n_out = 1
                for d in out_dims:
                    n_out *= d
                dot_flops += 2.0 * n_out * contracted * mult
            if in_fused:
                continue
            om = _OP_RE.search(ln)
            if not om or om.group(1) in _NO_TRAFFIC:
                continue
            dc = decl_re.search(ln)
            out_b = _shape_bytes(dc.group(2)) if dc else 0
            out_w += out_b * mult
            out_1 += out_b
    return {"dot_flops": dot_flops, "out_bytes_w": out_w, "out_bytes_1": out_1}


@dataclass
class RooflineTerms:
    """All byte/FLOP fields are PER-DEVICE; global = per-device x chips.

    Equivalently (the task formulas): compute_s = HLO_FLOPs_global /
    (chips x peak) — identical because HLO_FLOPs_global = flops x chips.
    """

    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HBM bytes accessed
    coll_bytes: float            # per-device collective wire bytes
    chips: int
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bound: str = ""
    model_flops: float = 0.0     # global useful flops (6*N*D style)
    useful_ratio: float = 0.0    # model_flops / (flops x chips)

    def finalize(self) -> "RooflineTerms":
        self.compute_s = self.flops / HW["peak_flops"]
        self.memory_s = self.hbm_bytes / HW["hbm_bw"]
        self.collective_s = self.coll_bytes / HW["ici_bw"]
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.bound = max(terms, key=terms.get)
        if self.flops > 0 and self.model_flops > 0:
            self.useful_ratio = self.model_flops / (self.flops * self.chips)
        return self


def roofline_terms(
    cost: dict,
    coll: CollectiveStats,
    chips: int,
    model_fl: float = 0.0,
    parsed: dict | None = None,
) -> RooflineTerms:
    """Combine XLA cost_analysis with the trip-count-weighted HLO parse.

    * FLOPs: the parsed dot census is exact per dot and trip-weighted; XLA's
      number visits while bodies once.  Take the max (non-dot flops only
      matter in programs with no loops, where cost_analysis wins).
    * bytes: XLA's per-instruction accounting is better (it models fusion
      operand slicing), but suffers the same once-per-while undercount —
      scale it by the parsed output-bytes ratio (weighted / once).
    """
    flops = float(cost.get("flops", 0.0) or 0.0)
    bytes_ = float(cost.get("bytes accessed", 0.0) or 0.0)
    if parsed:
        flops = max(flops, float(parsed.get("dot_flops", 0.0)))
        if "out_bytes_w" in parsed:
            ratio = parsed["out_bytes_w"] / max(parsed.get("out_bytes_1", 1.0), 1.0)
            bytes_ = bytes_ * max(ratio, 1.0)
        else:  # legacy artifact
            bytes_ = max(bytes_, float(parsed.get("hbm_bytes", 0.0)))
    rt = RooflineTerms(
        flops=flops, hbm_bytes=bytes_, coll_bytes=coll.total_bytes,
        chips=chips, model_flops=model_fl,
    )
    return rt.finalize()


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for training cells;
    2*N*D-style forward cost for serving cells (per step)."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    # active params per token (attention + ffn + embeddings out)
    attn = 2 * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim * L
    if cfg.n_experts:
        ff_active = 3 * d * cfg.d_expert * (cfg.moe_top_k + cfg.n_shared_experts)
        ff = ff_active * (L - cfg.moe_layer_start) + 3 * d * (cfg.d_ff_dense or cfg.d_ff) * cfg.moe_layer_start
    elif "ssm" in cfg.layer_pattern:
        d_in = cfg.d_inner_ssm
        ff = 0.0
        attn = L * (d * (2 * d_in + 2 * cfg.ssm_state + cfg.n_ssm_heads) + d_in * d)
    else:
        n_mlp = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
        ff = n_mlp * d * cfg.d_ff * L
        if "rglru" in cfg.layer_pattern:
            # rglru layers replace attention with recurrent params
            n_rec = sum(
                1 for i in range(cfg.n_layers)
                if (cfg.prefix_pattern + cfg.layer_pattern * cfg.n_groups)[i] == "rglru"
            )
            rec = n_rec * (2 * d * cfg.d_rnn + 2 * cfg.d_rnn * cfg.d_rnn + cfg.d_rnn * d)
            attn = attn * (L - n_rec) / L + rec
    n_active = attn + ff + d * V  # + unembed
    tokens = shape.global_batch * shape.seq_len
    if cfg.is_encdec:
        n_active *= 2.0  # encoder + decoder stacks
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n_active * shape.global_batch
