"""Configuration tuner — the paper's "find the optimal configuration" case.

The strategies now live in :mod:`repro.search.strategies`, running on the
chunked/sharded evaluator (:class:`repro.search.ChunkedEvaluator`) so the
same code drives the Hadoop job model and the TPU step model
(:mod:`repro.search.tpu`).  This module keeps the seed import path:

* :func:`grid_search`          — exhaustive Cartesian product, streamed with
  on-device top-k (exact optimum inside the grid; oracle in ``bench_tuner``).
* :func:`random_search`        — uniform sampling of the space.
* :func:`coordinate_descent`   — iterate per-parameter sweeps to a fixpoint.
"""

from __future__ import annotations

from repro.search.strategies import (
    TuningResult,
    coordinate_descent,
    grid_search,
    random_search,
)

__all__ = ["TuningResult", "grid_search", "random_search", "coordinate_descent"]
