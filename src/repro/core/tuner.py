"""Configuration tuner — the paper's "find the optimal configuration" use case.

Three strategies over the Hadoop parameter space, all driven by the
vectorized what-if engine so the model itself is never the bottleneck:

* :func:`grid_search`          — exhaustive Cartesian product (exact optimum
  inside the grid; used as the oracle in ``bench_tuner``).
* :func:`random_search`        — uniform sampling of the space.
* :func:`coordinate_descent`   — iterate per-parameter sweeps to a fixpoint;
  converges in a handful of model evaluations and, on the benchmark spaces,
  reaches the grid optimum (coordinate-wise quasi-convexity holds for the
  cost model in practice).

The same interfaces are reused by the TPU-side tuner
(:mod:`repro.core.tpu_model`) with a different cost function — the paper's
methodology transplanted to sharding/microbatch configuration.
"""

from __future__ import annotations

import random as _random
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from .hadoop.params import CostFactors, HadoopParams, ProfileStats
from .whatif import evaluate_grid, evaluate_product_grid

__all__ = ["TuningResult", "grid_search", "random_search", "coordinate_descent"]


@dataclass
class TuningResult:
    best_assignment: dict[str, float]
    best_cost: float
    evaluations: int
    history: list[tuple[dict[str, float], float]] = field(default_factory=list)

    def apply(self, p: HadoopParams) -> HadoopParams:
        """Materialize the winning assignment onto a HadoopParams object."""
        kw = {}
        for k, v in self.best_assignment.items():
            if k in p.__dataclass_fields__:
                f = p.__dataclass_fields__[k]
                if f.type in ("int", int):
                    kw[k] = int(round(v))
                elif f.type in ("bool", bool):
                    kw[k] = bool(round(v))
                else:
                    kw[k] = float(v)
        return p.replace(**kw)


def grid_search(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    space: Mapping[str, Sequence[float]],
) -> TuningResult:
    res = evaluate_product_grid(p, s, c, space)
    i, cost, assign = res.best()
    return TuningResult(assign, cost, evaluations=len(res.total_cost))


def random_search(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    space: Mapping[str, Sequence[float]],
    *,
    samples: int = 4096,
    seed: int = 0,
) -> TuningResult:
    rng = _random.Random(seed)
    keys = list(space.keys())
    overrides = {
        k: np.asarray([rng.choice(list(space[k])) for _ in range(samples)])
        for k in keys
    }
    res = evaluate_grid(p, s, c, overrides)
    i, cost, assign = res.best()
    return TuningResult(assign, cost, evaluations=samples)


def coordinate_descent(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    space: Mapping[str, Sequence[float]],
    *,
    max_rounds: int = 8,
) -> TuningResult:
    keys = list(space.keys())
    # Start from the mid-point of every axis.
    assign = {k: float(space[k][len(space[k]) // 2]) for k in keys}
    evals = 0
    history: list[tuple[dict[str, float], float]] = []
    best_cost = np.inf

    for _ in range(max_rounds):
        changed = False
        for k in keys:
            cand = np.asarray(list(space[k]), dtype=np.float64)
            overrides: dict[str, np.ndarray] = {k: cand}
            for k2 in keys:
                if k2 != k:
                    overrides[k2] = np.full(len(cand), assign[k2])
            res = evaluate_grid(p, s, c, overrides)
            evals += len(cand)
            i = int(np.argmin(res.total_cost))
            if res.total_cost[i] < best_cost - 1e-12:
                best_cost = float(res.total_cost[i])
                if assign[k] != float(cand[i]):
                    assign[k] = float(cand[i])
                    changed = True
            history.append((dict(assign), best_cost))
        if not changed:
            break

    return TuningResult(dict(assign), float(best_cost), evals, history)
