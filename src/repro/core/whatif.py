"""What-if engine: vectorized evaluation of the job model over config grids.

The paper's models exist to answer *what-if* questions ("what happens to job
cost if ``io.sort.mb`` doubles and compression is enabled?") and to search
the configuration space.  The JAX formulation (:mod:`repro.core.hadoop.model`)
makes this massively parallel: a single ``jit(vmap(job_model_jnp))`` call
evaluates ~10^5-10^6 full job models at once — the engine the tuner and the
``bench_whatif`` benchmark build on.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .hadoop.model import job_model_jnp, pack_config
from .hadoop.params import CostFactors, HadoopParams, ProfileStats

__all__ = ["WhatIfResult", "evaluate_grid", "evaluate_product_grid"]


@dataclass
class WhatIfResult:
    """Batched model outputs plus the override grid that produced them."""

    overrides: dict[str, np.ndarray]    # key -> (B,) values
    outputs: dict[str, np.ndarray]      # model key -> (B,) values
    total_cost: np.ndarray              # (B,) seconds (inf where invalid)

    def best(self) -> tuple[int, float, dict[str, float]]:
        """Index, cost and override assignment of the cheapest valid config."""
        i = int(np.argmin(self.total_cost))
        return i, float(self.total_cost[i]), {
            k: float(v[i]) for k, v in self.overrides.items()
        }


@jax.jit
def _eval_batched(cfg: dict) -> dict:
    batched = {k: v for k, v in cfg.items() if jnp.ndim(v) > 0}
    static = {k: v for k, v in cfg.items() if jnp.ndim(v) == 0}

    def one(b):
        return job_model_jnp({**static, **b})

    return jax.vmap(one)(batched)


def evaluate_grid(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    overrides: Mapping[str, Any],
) -> WhatIfResult:
    """Evaluate the job model with some parameters swept as (B,) arrays.

    ``overrides`` maps config keys (any field of the three dataclasses) to a
    1-D array of values; all arrays must have the same length B.  Scalar
    overrides are allowed and applied unbatched.
    """
    cfg = pack_config(p, s, c)
    n = None
    ov_arrays: dict[str, np.ndarray] = {}
    for k, v in overrides.items():
        if k not in cfg:
            raise KeyError(f"unknown config key: {k!r}")
        arr = jnp.asarray(v, dtype=cfg[k].dtype)
        if arr.ndim > 0:
            if n is None:
                n = arr.shape[0]
            elif arr.shape[0] != n:
                raise ValueError("all batched overrides must share a length")
            ov_arrays[k] = np.asarray(arr)
        cfg[k] = arr
    if n is None:
        raise ValueError("at least one override must be batched")

    out = _eval_batched(cfg)
    out_np = {k: np.asarray(v) for k, v in out.items()}
    total = np.where(out_np["valid"] > 0, out_np["j_totalCost"], np.inf)
    return WhatIfResult(overrides=ov_arrays, outputs=out_np, total_cost=total)


def evaluate_product_grid(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    space: Mapping[str, Sequence[float]],
    *,
    chunk: int = 1 << 16,
) -> WhatIfResult:
    """Cartesian-product sweep over ``space`` (key -> candidate values).

    The product is materialized lazily and evaluated in chunks so arbitrarily
    large grids stream through the jitted batched model.
    """
    keys = list(space.keys())
    combos = itertools.product(*[space[k] for k in keys])
    all_over: dict[str, list] = {k: [] for k in keys}
    all_out: dict[str, list] = {}
    totals: list[np.ndarray] = []

    def flush(block: list[tuple]) -> None:
        if not block:
            return
        cols = list(zip(*block))
        ov = {k: np.asarray(col, dtype=np.float64) for k, col in zip(keys, cols)}
        res = evaluate_grid(p, s, c, ov)
        for k in keys:
            all_over[k].append(ov[k])
        for k, v in res.outputs.items():
            all_out.setdefault(k, []).append(v)
        totals.append(res.total_cost)

    block: list[tuple] = []
    for combo in combos:
        block.append(combo)
        if len(block) >= chunk:
            flush(block)
            block = []
    flush(block)

    return WhatIfResult(
        overrides={k: np.concatenate(v) for k, v in all_over.items()},
        outputs={k: np.concatenate(v) for k, v in all_out.items()},
        total_cost=np.concatenate(totals),
    )
