"""What-if engine: vectorized evaluation of the job model over config grids.

The engine now lives in :mod:`repro.search` — a chunked, padded, device-
sharded evaluator with streaming top-k and an exact-simulator escape hatch
for ``valid == 0`` configs.  This module keeps the seed API:

* :class:`WhatIfResult` (= :class:`repro.search.SearchResult`) — batched
  outputs + overrides; ``best()`` raises :class:`InvalidGridError` on an
  all-invalid grid instead of silently returning index 0.
* :func:`evaluate_grid` — parameters swept as (B,) arrays.
* :func:`evaluate_product_grid` — streamed Cartesian sweep.
* :func:`evaluate_queries` — MANY heterogeneous queries at once, resolved
  concurrently through :class:`repro.search.service.WhatIfService` (the
  multi-query path: probes/sweeps/grids are coalesced into shared evaluator
  chunks instead of paying one padded ``evaluate`` call each).
"""

from __future__ import annotations

from typing import Any, Mapping, Sequence

import numpy as np

from repro.search.evaluator import (
    ChunkedEvaluator,
    InvalidGridError,
    SearchResult,
    cached_evaluator,
    evaluate_unchunked,
)
from repro.search.grid import iter_blocks
from repro.search.service import QueryResult, WhatIfService

from .hadoop.params import CostFactors, HadoopParams, ProfileStats

__all__ = [
    "WhatIfResult",
    "InvalidGridError",
    "evaluate_grid",
    "evaluate_product_grid",
    "evaluate_queries",
    "WhatIfService",
]

# The seed name; one dataclass serves both the legacy and search APIs.
WhatIfResult = SearchResult


def evaluate_grid(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    overrides: Mapping[str, Any],
    *,
    chunk: int | None = None,
    evaluator: ChunkedEvaluator | None = None,
) -> WhatIfResult:
    """Evaluate the job model with some parameters swept as (B,) arrays.

    ``overrides`` maps config keys (any field of the three dataclasses) to a
    1-D array of values; all arrays must have the same length B.  Scalar
    overrides are allowed and applied unbatched.  Evaluation streams through
    the chunked sharded evaluator (bit-for-bit equal to the seed's single
    ``jit(vmap(...))`` call).
    """
    if evaluator is None:
        evaluator = cached_evaluator(p, s, c, chunk)
    return evaluator.evaluate(overrides)


def evaluate_product_grid(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    space: Mapping[str, Sequence[float]],
    *,
    chunk: int = 1 << 13,
    evaluator: ChunkedEvaluator | None = None,
) -> WhatIfResult:
    """Cartesian-product sweep over ``space`` (key -> candidate values).

    The product is never materialized: index blocks stream through the
    fixed-size chunked evaluator, so arbitrarily large grids run in bounded
    device memory with a single XLA compile.  (For 10^5+-config spaces
    prefer :func:`repro.search.search_topk`, which keeps only the top-k
    instead of returning every output column.)
    """
    if evaluator is None:
        evaluator = cached_evaluator(p, s, c, chunk)
    parts: list[WhatIfResult] = [
        evaluator.evaluate(cols) for _, cols in iter_blocks(space, evaluator.chunk)
    ]
    return WhatIfResult(
        overrides={k: np.concatenate([r.overrides[k] for r in parts])
                   for k in parts[0].overrides},
        outputs={k: np.concatenate([r.outputs[k] for r in parts])
                 for k in parts[0].outputs},
        total_cost=np.concatenate([r.total_cost for r in parts]),
    )


def evaluate_queries(
    p: HadoopParams,
    s: ProfileStats,
    c: CostFactors,
    queries: Sequence[Mapping[str, Any]],
    *,
    chunk: int | None = None,
    exact_fallback: bool = False,
    evaluator: ChunkedEvaluator | None = None,
) -> list[QueryResult]:
    """Answer many what-if queries in one coalesced pass.

    Each query is an override mapping in the :func:`evaluate_grid` format
    (scalars broadcast, 1-D arrays sweep).  All queries share one admission
    queue and one compiled evaluator executable; results are bit-for-bit
    what per-query :func:`evaluate_grid` calls would return, but heterogen-
    eous small queries no longer pay a padded chunk evaluation each.  With
    ``exact_fallback`` rows whose closed-form model is out of domain are
    re-costed through the task-scheduler simulator instead of ``inf``.
    """
    if evaluator is None:
        evaluator = cached_evaluator(p, s, c, chunk)
    with WhatIfService(evaluator) as svc:
        return svc.map(queries, exact_fallback=exact_fallback)
