"""TPU adaptation of the paper's analytical performance models.

The paper predicts MapReduce job cost from three parameter groups
(Hadoop config / profile statistics / cost factors) by decomposing
execution into phases and summing per-phase closed-form costs (Eq. 98:
``Cost = IOCost + CPUCost + NETCost``).  This module is the same
methodology for a TPU training/serving step:

  Table-1 analogue : :class:`TpuParams` — mesh axes, microbatch count,
                     remat policy, activation dtype, sharding strategy.
  Table-2 analogue : derived *dataflow statistics* per phase — tensor
                     sizes/FLOPs from the architecture config x input shape
                     (the "profile" is exact here: shapes are static).
  Table-3 analogue : :class:`TpuCostFactors` — peak FLOP/s, HBM B/s,
                     ICI B/s, plus dimensionless efficiency factors that
                     can be *fitted* from dry-run artifacts exactly the way
                     Starfish fits Table 3 from live task timings.

  Phases (map/reduce analogue): embed -> per-layer {qkv, attn, proj,
  mlp|moe(+dispatch shuffle)} -> logits -> loss -> backward(2x) ->
  grad-reduce -> optimizer.  Each phase yields (flops, hbm bytes,
  collective bytes) per device; Eq. 98's three terms fall out by dividing
  by the three hardware rates, and the job-level composition over
  microbatches mirrors Eqs. 92-97 (waves of tasks -> sequential
  microbatches on the same chips).

Predictions are validated against the compiled dry-run's parsed HLO in
``benchmarks/bench_tpu_model.py`` (E9) — the paper's "models vs live run"
experiment, with XLA as the live system.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.roofline import HW
from repro.models.config import ModelConfig

__all__ = ["TpuParams", "TpuCostFactors", "PhaseCost", "StepModel", "step_model"]


@dataclass(frozen=True)
class TpuParams:
    """Table-1 analogue: the tunable execution configuration."""
    dp: int = 16                  # data-parallel ways (pod x data)
    tp: int = 16                  # tensor/model-parallel ways
    n_micro: int = 8              # gradient-accumulation microbatches
    remat: bool = True            # recompute activations in backward
    act_bytes: int = 2            # bf16 activations
    grad_bytes: int = 4           # fp32 grad accumulators / collectives
    param_bytes: int = 4          # fp32 master params
    seq_shard: bool = False       # sequence-parallel norm/residual regions
    ep: int = 1                   # expert-parallel ways (<= tp)

    @property
    def chips(self) -> int:
        return self.dp * self.tp


@dataclass(frozen=True)
class TpuCostFactors:
    """Table-3 analogue.  Efficiency factors default to 1 (pure roofline)
    and are fitted from dry-run artifacts by benchmarks/bench_tpu_model."""
    peak_flops: float = HW["peak_flops"]
    hbm_bw: float = HW["hbm_bw"]
    ici_bw: float = HW["ici_bw"]
    # dimensionless fudge factors (≥1 inflates cost), fitted like Table 3:
    eff_compute: float = 1.0      # MXU utilization / padding waste
    eff_memory: float = 1.0       # fusion quality (re-reads of activations)
    eff_collective: float = 1.0   # link utilization / latency


@dataclass
class PhaseCost:
    """Per-phase (FLOPs, HBM bytes, collective bytes) — per device."""
    name: str
    flops: float = 0.0
    hbm: float = 0.0
    coll: float = 0.0

    def scaled(self, k: float) -> "PhaseCost":
        return PhaseCost(self.name, self.flops * k, self.hbm * k, self.coll * k)


@dataclass
class StepModel:
    """Job-level model: phase list + the paper's three cost terms."""
    phases: list = field(default_factory=list)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    @property
    def bound(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def total_s(self) -> float:
        """Eq. 98 analogue — upper bound without overlap."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def overlap_s(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)


def _layer_counts(cfg: ModelConfig) -> dict:
    """How many layers of each mixer kind the model has."""
    kinds = list(cfg.prefix_pattern)
    if cfg.n_experts and cfg.moe_layer_start:
        kinds += ["attn"] * cfg.moe_layer_start
    n_scan = cfg.n_layers - len(kinds)
    reps = n_scan // cfg.pattern_len
    for k in cfg.layer_pattern:
        kinds += [k] * reps
    out: dict[str, int] = {}
    for k in kinds:
        out[k] = out.get(k, 0) + 1
    return out


def step_model(
    cfg: ModelConfig,
    shape,                        # repro.configs.shapes.Shape
    tp_params: TpuParams,
    costs: TpuCostFactors = TpuCostFactors(),
) -> StepModel:
    """Phase-decomposed analytical cost of one train/serve step.

    Dataflow statistics are exact (static shapes); the model's job is the
    same as the paper's: predict the three resource terms for a *candidate
    configuration without running it*, so a tuner can search the config
    space (see ``repro.core.tuner`` and the what-if engine).
    """
    P = tp_params
    d, V = cfg.d_model, cfg.vocab_size
    ab, gb, pb = P.act_bytes, P.grad_bytes, P.param_bytes
    is_train = shape.kind == "train"
    # tokens processed per device per microbatch
    if shape.kind == "decode":
        tokens_global = shape.global_batch          # one token per sequence
    else:
        tokens_global = shape.global_batch * shape.seq_len
    t_dev = tokens_global / max(P.dp, 1) / max(P.n_micro if is_train else 1, 1)

    counts = _layer_counts(cfg)
    phases: list[PhaseCost] = []

    def add(name, flops=0.0, hbm=0.0, coll=0.0):
        phases.append(PhaseCost(name, flops, hbm, coll))

    # ---------------- embed ----------------
    add("embed", hbm=t_dev * d * ab + t_dev * 4)     # gather reads + ids

    # ---------------- per-layer phases ----------------
    n_attn = counts.get("attn", 0) + counts.get("local", 0) + counts.get("attn_dense", 0)
    n_rglru = counts.get("rglru", 0)
    n_ssm = counts.get("ssm", 0)

    # GSPMD divisibility rule: a head dim that the tp axis does not divide
    # is REPLICATED (XLA's "involuntary full rematerialization") — the
    # model charges the full head count, which is exactly what the
    # starcoder2 dry-run measured (36 heads at tp=16; §Perf Cell C).
    def _shard(n: int) -> int:
        tp = max(P.tp, 1)
        if not n:
            return 0
        if n % tp == 0:
            return n // tp
        if tp % n == 0:
            return 1          # partial shard + replicate groups (kv=8@tp=16)
        return n              # incompatible -> GSPMD replicates (36@tp=16)

    heads_dev = _shard(cfg.n_heads)
    kv_dev = _shard(cfg.n_kv_heads)
    hd = cfg.head_dim

    if n_attn:
        # qkv+proj matmuls (TP-sharded over heads)
        w_qkvo = d * (heads_dev + 2 * kv_dev + heads_dev) * hd
        add(
            "attn_proj",
            flops=n_attn * 2.0 * t_dev * w_qkvo,
            hbm=n_attn * (w_qkvo * pb + t_dev * (2 * d) * ab),
        )
        # scores+values: seq_len context per token (window for local layers)
        ctx_full = shape.seq_len
        n_local = counts.get("local", 0)
        n_global = n_attn - n_local
        ctx_local = min(cfg.window_size, shape.seq_len)
        att_fl = 2.0 * 2.0 * t_dev * hd * heads_dev
        add(
            "attn_scores",
            flops=att_fl * (n_global * ctx_full + n_local * ctx_local) / 2
            if shape.kind != "decode"
            else att_fl * (n_global * ctx_full + n_local * ctx_local),
            hbm=(n_global * ctx_full + n_local * ctx_local)
            * kv_dev * hd * ab * (2 if shape.kind == "decode" else 0)
            + n_attn * t_dev * hd * heads_dev * ab * 2,
        )
        # TP collective: 2 all-reduces (attn out + mlp out) per layer in
        # Megatron layout = 2 x 2x activation bytes (ring) — fwd; bwd adds 2.
        if P.tp > 1:
            ar = 2.0 * t_dev * d * (gb if is_train else ab)
            add("tp_allreduce", coll=n_attn * 2 * ar)

    if n_rglru:
        dr = cfg.d_rnn
        # Griffin block: two d->dr input branches + dr->d out proj; the
        # RG-LRU gates themselves are diagonal (O(dr) per token, negligible)
        w = (2 * d * dr + dr * d) / max(P.tp, 1)
        add(
            "rglru",
            flops=n_rglru * 2.0 * t_dev * w,
            hbm=n_rglru * (w * pb + t_dev * (d + dr) * ab * 2),
        )
    if n_ssm:
        din = cfg.d_inner_ssm
        w = d * (2 * din + 2 * cfg.ssm_state + cfg.n_ssm_heads) + din * d
        add(
            "ssm",
            flops=n_ssm * 2.0 * t_dev * (w / max(P.tp, 1))
            + n_ssm * 2.0 * t_dev * din * cfg.ssm_state * 2 / max(P.tp, 1),
            hbm=n_ssm * (w * pb / max(P.tp, 1) + t_dev * din * ab * 4),
        )

    # ---------------- FFN / MoE ----------------
    if cfg.n_experts:
        n_moe = cfg.n_layers - cfg.moe_layer_start
        k_act = cfg.moe_top_k + cfg.n_shared_experts
        ff_w = 3 * d * cfg.d_expert          # swiglu expert
        cap = cfg.moe_capacity_factor
        # ideal: only top-k experts' flops per token (+ capacity padding)
        add(
            "moe_experts",
            flops=n_moe * 2.0 * t_dev * k_act * ff_w * cap,
            hbm=n_moe * (cfg.n_experts * ff_w * pb / max(P.ep, 1)
                         + t_dev * k_act * cfg.d_expert * ab * 2 * cap),
        )
        add("moe_router", flops=n_moe * 2.0 * t_dev * d * cfg.n_experts)
        # dispatch shuffle: all_to_all of top-k token activations (the
        # paper's Eq. 90 analogue — this IS the shuffle)
        if P.ep > 1:
            a2a = t_dev * cfg.moe_top_k * d * ab * cap
            add("moe_shuffle", coll=n_moe * 2.0 * a2a)  # there + back
        if cfg.moe_layer_start:
            w = 3 * d * (cfg.d_ff_dense or cfg.d_ff) / max(P.tp, 1)
            add("dense_ffn", flops=cfg.moe_layer_start * 2.0 * t_dev * w,
                hbm=cfg.moe_layer_start * w * pb)
    elif cfg.d_ff:
        n_ffn = n_attn + n_rglru
        n_mats = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
        w = n_mats * d * cfg.d_ff / max(P.tp, 1)
        add(
            "ffn",
            flops=n_ffn * 2.0 * t_dev * w,
            hbm=n_ffn * (w * pb + t_dev * (cfg.d_ff / max(P.tp, 1)) * ab * 2),
        )

    # ---------------- norms/residuals (memory-only) ----------------
    add("norms_residuals", hbm=cfg.n_layers * t_dev * d * ab * 6)

    # ---------------- logits + loss ----------------
    v_dev = V / max(P.tp, 1)
    lg_tokens = t_dev if shape.kind != "prefill" else t_dev  # full logits
    if shape.kind == "decode":
        lg_tokens = t_dev
    add(
        "logits",
        flops=2.0 * lg_tokens * d * v_dev,
        hbm=d * v_dev * pb + lg_tokens * v_dev * 4,
    )
    if is_train:
        add("loss", hbm=lg_tokens * v_dev * 4 * 2)

    # ---------------- encoder stack (enc-dec) ----------------
    if cfg.is_encdec:
        # encoder ~ mirror of the decoder's attn+ffn phases (bidirectional)
        enc = [p.scaled(cfg.n_enc_layers / max(cfg.n_layers, 1))
               for p in phases if p.name in ("attn_proj", "attn_scores", "ffn")]
        for p in enc:
            add("encoder_" + p.name, p.flops, p.hbm, p.coll)

    # ---------------- backward + optimizer (train only) ----------------
    if is_train:
        bwd = []
        for p in phases:
            if p.name.startswith(("tp_allreduce", "moe_shuffle")):
                bwd.append(PhaseCost("bwd_" + p.name, 0, 0, p.coll))
            else:
                k = 2.0 + (1.0 if P.remat else 0.0)  # recompute fwd in bwd
                bwd.append(PhaseCost("bwd_" + p.name, p.flops * k,
                                     p.hbm * 2.0, 0.0))
        phases.extend(bwd)

        # parameter/optimizer traffic: params sharded over tp (and ep)
        n_params = _param_count(cfg)
        p_dev = n_params / max(P.tp, 1)
        add("optimizer", hbm=p_dev * (pb * 2 + gb * 2 + 8))  # m,v,p,g
        # DP gradient all-reduce (ring): 2x grad bytes, off-critical-path
        # per-microbatch if overlapped; modeled once per step.
        if P.dp > 1:
            add("grad_reduce", coll=2.0 * p_dev * gb)

    # ---------------- microbatch composition (Eqs. 92-97 analogue) -------
    n_rep = P.n_micro if is_train else 1
    total = StepModel(phases=phases)
    for p in phases:
        rep = 1 if p.name in ("optimizer", "grad_reduce") else n_rep
        total.compute_s += p.flops * rep / (costs.peak_flops / costs.eff_compute)
        total.memory_s += p.hbm * rep / (costs.hbm_bw / costs.eff_memory)
        total.collective_s += p.coll * rep / (costs.ici_bw / costs.eff_collective)
    return total


def _param_count(cfg: ModelConfig) -> float:
    d, V = cfg.d_model, cfg.vocab_size
    counts = _layer_counts(cfg)
    n_attn = counts.get("attn", 0) + counts.get("local", 0)
    n = V * d * (1 if cfg.tie_embeddings else 2)
    n += n_attn * d * (cfg.n_heads + 2 * cfg.n_kv_heads + cfg.n_heads) * cfg.head_dim / 2
    if cfg.n_experts:
        n += (cfg.n_layers - cfg.moe_layer_start) * cfg.n_experts * 3 * d * cfg.d_expert
        n += (cfg.n_layers - cfg.moe_layer_start) * cfg.n_shared_experts * 3 * d * cfg.d_expert
        n += cfg.moe_layer_start * 3 * d * (cfg.d_ff_dense or cfg.d_ff)
    elif cfg.d_ff:
        n_mats = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
        n += (n_attn + counts.get("rglru", 0)) * n_mats * d * cfg.d_ff
    if counts.get("rglru"):
        dr = cfg.d_rnn
        n += counts["rglru"] * (2 * d * dr + dr * d + 2 * dr * dr)
    if counts.get("ssm"):
        din = cfg.d_inner_ssm
        n += counts["ssm"] * (d * (2 * din + 2 * cfg.ssm_state + cfg.n_ssm_heads) + din * d)
    if cfg.is_encdec:
        n *= 1.8
    return float(n)
